"""ISSUE 9 tentpole contracts: the front door under failure and overload.

* A poisoned batch fails ONLY its own future — healthy riders are retried
  singly and answered, and the dispatcher thread survives (the blanket
  except-and-die regression).
* The supervised dispatcher restarts on a loop bug, and after exhausting
  its restart budget declares the front door dead: queued futures fail and
  new submits fast-fail with 429 "unavailable".
* The circuit breaker opens on persistent device failure and fast-fails
  submits with an honest retry hint.
* The stuck-device watchdog 504s in-flight futures with ``DeviceStuck``
  instead of hanging clients.
* The degradation ladder threads ``degrade=N`` to the server, stamps
  results ``degraded``, sheds only the strictly-lowest priority class at
  L3, and auto-recovers.
* End to end on the real engine: L1 shrinks the rerank pool, L2 answers
  sketch-only with Theorem 5.1 upper-bound scores.
"""

import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.fault.degrade import DegradeConfig
from repro.fault.retry import CircuitBreaker
from repro.obs import MetricsRegistry
from repro.serving.frontend import (DeadlineExceeded, DeviceStuck,
                                    FrontendServer, Rejected,
                                    ServingFrontend, TenantQuota)
from repro.serving.results import QueryResult
from repro.serving.serve import QueryServer

DS = synth.SparseDatasetSpec("fr", n=400, psi_doc=20, psi_query=10,
                             value_dist="gaussian")

POISON = 12345.0        # marker value: a malformed query the device rejects


def _q(seed=0, nnz=8, poison=False):
    rng = np.random.default_rng(seed)
    qi = rng.choice(DS.n, nnz, replace=False).astype(np.int32)
    qv = rng.random(nnz, np.float32)
    if poison:
        qv[0] = POISON
    return qi, qv


class _StubServer:
    """Degrade-aware device stand-in: rejects poisoned rows, records the
    ladder level of every dispatch, optional stall gate."""

    def __init__(self, k=4, gate: threading.Event = None):
        self.k = k
        self.gate = gate
        self.calls = []          # (batch_rows, degrade_level)

    def query_many(self, qi, qv, ctx=None, degrade=0):
        if self.gate is not None:
            self.gate.wait()
        self.calls.append((qi.shape[0], degrade))
        if np.any(qv == POISON):
            raise ValueError("malformed query rejected by device")
        B = qi.shape[0]
        ids = np.tile(np.arange(self.k, dtype=np.int64), (B, 1))
        return QueryResult(ids=ids, scores=np.zeros((B, self.k), np.float32),
                           k=self.k, backend="stub", trace_id="q-stub",
                           degraded=degrade > 0)


class _LoopBug(BaseException):
    """Escapes the batch-level ``except Exception`` — models a bug in the
    dispatch loop itself, which only the supervisor can catch."""


class _BuggyServer(_StubServer):
    def query_many(self, qi, qv, ctx=None, degrade=0):
        raise _LoopBug("dispatch loop bug")


# ---------------------------------------------------------------------------
# poisoned batch (satellite: the blanket-except regression)
# ---------------------------------------------------------------------------

def test_poisoned_batch_fails_only_its_own_future():
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    reg = MetricsRegistry()
    fe = ServingFrontend(stub, max_batch=8, batch_window_ms=5.0,
                         queue_depth=32, registry=reg)
    try:
        healthy = [fe.submit(*_q(seed=s)) for s in range(3)]
        bad = fe.submit(*_q(seed=9, poison=True))
        gate.set()                        # release one coalesced batch
        for f in healthy:
            out = f.result(timeout=30)    # riders answered via single retry
            assert out.ids.shape == (4,)
        with pytest.raises(ValueError, match="malformed"):
            bad.result(timeout=30)
        # the dispatcher survived: a fresh query still gets served
        assert fe.query(*_q(seed=5)).ids.shape == (4,)
        assert fe.dispatcher_restarts == 0
        assert fe._dispatcher.is_alive()
        # a poisoned query is not a broken device: breaker stays closed
        assert fe.breaker.state == "closed"
    finally:
        fe.close()
    coalesced = max(rows for rows, _ in stub.calls)
    assert coalesced > 1, f"batch never coalesced: {stub.calls}"
    snap = json.loads(reg.to_json())
    by_outcome = {}
    for s in snap["repro_frontend_requests_total"]["series"]:
        out = s["labels"]["outcome"]
        by_outcome[out] = by_outcome.get(out, 0) + s["value"]
    assert by_outcome["ok"] == 4 and by_outcome["error"] == 1


def test_single_query_batch_fails_directly_without_retry():
    stub = _StubServer()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=MetricsRegistry())
    try:
        with pytest.raises(ValueError):
            fe.query(*_q(poison=True))
        assert len(stub.calls) == 1       # no pointless single-row retry
        assert fe.query(*_q()).ids.shape == (4,)
    finally:
        fe.close()


# ---------------------------------------------------------------------------
# supervised dispatcher
# ---------------------------------------------------------------------------

def test_dispatcher_exhausts_restarts_then_fast_fails():
    reg = MetricsRegistry()
    fe = ServingFrontend(_BuggyServer(), max_batch=1, batch_window_ms=0.0,
                         queue_depth=8, registry=reg,
                         max_dispatcher_restarts=1)
    try:
        fe.submit(*_q(seed=0))            # crash 1: restart
        time.sleep(0.05)
        fe.submit(*_q(seed=1))            # crash 2: budget exhausted -> dead
        deadline = time.time() + 5
        while not fe._dispatcher_dead and time.time() < deadline:
            time.sleep(0.01)
        assert fe._dispatcher_dead
        assert fe.dispatcher_restarts == 2
        with pytest.raises(Rejected) as exc:
            fe.submit(*_q(seed=2))
        assert exc.value.reason == "unavailable"
        assert exc.value.retry_after_ms > 0
        snap = json.loads(reg.to_json())
        assert snap["repro_frontend_dispatcher_restarts_total"][
            "series"][0]["value"] == 2
    finally:
        fe.close()


# ---------------------------------------------------------------------------
# circuit breaker fast-fail
# ---------------------------------------------------------------------------

def test_breaker_opens_on_persistent_device_failure():
    class _Broken(_StubServer):
        def query_many(self, qi, qv, ctx=None, degrade=0):
            raise RuntimeError("device on fire")

    reg = MetricsRegistry()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=60.0,
                        name="frontend", registry=reg)
    fe = ServingFrontend(_Broken(), max_batch=1, batch_window_ms=0.0,
                         queue_depth=8, registry=reg, breaker=br)
    try:
        with pytest.raises(RuntimeError, match="on fire"):
            fe.query(*_q())
        assert br.state == "open"
        with pytest.raises(Rejected) as exc:      # fast-fail, no queueing
            fe.submit(*_q())
        assert exc.value.reason == "unavailable"
        assert 0 < exc.value.retry_after_ms <= 60_000
        snap = json.loads(reg.to_json())
        rej = {s["labels"]["reason"]: s["value"] for s in
               snap["repro_frontend_rejected_total"]["series"]}
        assert rej == {"unavailable": 1}
        assert snap["repro_fault_breaker_open_total"][
            "series"][0]["value"] == 1
    finally:
        fe.close()


def test_halfopen_probe_survives_admission_and_expiry():
    """Regression: the half-open probe token must be consumed at dispatch
    time, not admission.  Under the old code a request that expired in
    queue (or was throttled/queue-full) consumed the probe in submit()
    and never reported an outcome, wedging the breaker into 429
    "unavailable" forever even after the device recovered."""

    class _FailOnce(_StubServer):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.fail_next = True

        def query_many(self, qi, qv, ctx=None, degrade=0):
            if self.fail_next:
                self.fail_next = False
                raise RuntimeError("transient device fault")
            return super().query_many(qi, qv, ctx=ctx, degrade=degrade)

    reg = MetricsRegistry()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=0.05,
                        name="frontend", registry=reg)
    fe = ServingFrontend(_FailOnce(), max_batch=1, batch_window_ms=0.0,
                         queue_depth=8, registry=reg, breaker=br)
    try:
        with pytest.raises(RuntimeError, match="transient"):
            fe.query(*_q())
        assert br.state == "open"
        time.sleep(0.08)                   # reset elapsed -> half-open
        # a request that expires in-queue must not strand the probe
        fut = fe.submit(*_q(), deadline_ms=0.0)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=30)
        # the device healed: the next real dispatch IS the probe, and its
        # recorded success closes the breaker
        assert fe.query(*_q()).ids.shape == (4,)
        assert br.state == "closed"
    finally:
        fe.close()


def test_queued_requests_fast_fail_when_breaker_opens():
    """Requests admitted before the breaker opened are 429'd by the
    dispatcher instead of being burned on a known-broken device."""

    class _GatedBroken(_StubServer):
        def query_many(self, qi, qv, ctx=None, degrade=0):
            if self.gate is not None:
                self.gate.wait()
            raise RuntimeError("device on fire")

    gate = threading.Event()
    reg = MetricsRegistry()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=60.0,
                        name="frontend", registry=reg)
    fe = ServingFrontend(_GatedBroken(gate=gate), max_batch=1,
                         batch_window_ms=0.0, queue_depth=8,
                         registry=reg, breaker=br)
    try:
        futs = [fe.submit(*_q(seed=s)) for s in range(3)]
        gate.set()             # first dispatch fails -> breaker opens
        with pytest.raises(RuntimeError, match="on fire"):
            futs[0].result(timeout=30)
        for f in futs[1:]:     # already-queued riders fast-fail
            with pytest.raises(Rejected) as exc:
                f.result(timeout=30)
            assert exc.value.reason == "unavailable"
            assert exc.value.retry_after_ms > 0
    finally:
        fe.close()


def test_loop_crash_fails_inflight_batch_futures():
    """Regression: a crash in the post-dispatch path (outside the batch
    try/except) restarts the loop via the supervisor — but the popped
    batch's futures must fail with the escaping error, not hang clients
    blocked in query() forever."""

    class _BadRow:
        def row(self, i, k=None, trace_id=None):
            raise RuntimeError("post-dispatch result decode bug")

    class _BadRowOnce(_StubServer):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.poisoned = True

        def query_many(self, qi, qv, ctx=None, degrade=0):
            if self.poisoned:
                self.poisoned = False
                return _BadRow()
            return super().query_many(qi, qv, ctx=ctx, degrade=degrade)

    reg = MetricsRegistry()
    fe = ServingFrontend(_BadRowOnce(), max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=reg)
    try:
        fut = fe.submit(*_q())
        with pytest.raises(RuntimeError, match="decode bug"):
            fut.result(timeout=30)         # fails fast instead of hanging
        assert fe.query(*_q()).ids.shape == (4,)   # restarted loop serves
        assert fe.dispatcher_restarts == 1
        assert fe._dispatcher.is_alive()
    finally:
        fe.close()


def test_housekeeping_survives_slo_exception():
    """A bug in the SLO signal must not silently kill the housekeeping
    thread (it carries the watchdog AND the ladder): the exception is
    counted and the loop keeps ticking."""

    class _BurningSLO:
        def fast_burn(self):
            raise KeyError("windows")

    reg = MetricsRegistry()
    fe = ServingFrontend(_StubServer(), max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=reg, slo=_BurningSLO(),
                         degrade=DegradeConfig(dwell_ticks=1),
                         degrade_tick_s=0.01)
    try:
        def errors():
            snap = json.loads(reg.to_json())
            fam = snap.get("repro_frontend_housekeeping_errors_total")
            return fam["series"][0]["value"] if fam else 0

        deadline = time.time() + 5
        while errors() < 2 and time.time() < deadline:
            time.sleep(0.01)
        assert errors() >= 2               # kept ticking after the first
        assert fe._housekeeper.is_alive()
        assert fe.query(*_q()).ids.shape == (4,)
    finally:
        fe.close()


# ---------------------------------------------------------------------------
# stuck-device watchdog
# ---------------------------------------------------------------------------

def test_watchdog_504s_inflight_futures_on_stall():
    gate = threading.Event()              # never set while the query waits
    stub = _StubServer(gate=gate)
    reg = MetricsRegistry()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=reg,
                         watchdog_timeout_s=0.15)
    try:
        fut = fe.submit(*_q())
        with pytest.raises(DeviceStuck) as exc:
            fut.result(timeout=30)
        assert isinstance(exc.value, DeadlineExceeded)   # same 504 path
        assert exc.value.queued_ms >= 150.0              # time stuck
        assert exc.value.deadline_ms == pytest.approx(150.0)
        snap = json.loads(reg.to_json())
        assert snap["repro_frontend_watchdog_trips_total"][
            "series"][0]["value"] == 1
        outcomes = {s["labels"]["outcome"]: s["value"] for s in
                    snap["repro_frontend_requests_total"]["series"]}
        assert outcomes.get("stuck") == 1
        assert fe.breaker.snapshot()[1] >= 1             # failure recorded
    finally:
        gate.set()                        # unblock the dispatcher for close
        fe.close()
    # the dispatch eventually returned; its set_result lost the race
    # cleanly (no InvalidStateError escaped the dispatcher).
    assert fe.dispatcher_restarts == 0


# ---------------------------------------------------------------------------
# degradation ladder through the front door
# ---------------------------------------------------------------------------

def _force_level(fe, level):
    for _ in range(level):
        fe.degrade.tick(burn=100.0, queue_frac=1.0)
    assert fe.degrade.level == level


def test_ladder_threads_degrade_level_to_server():
    stub = _StubServer()
    reg = MetricsRegistry()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=reg,
                         degrade=DegradeConfig(dwell_ticks=1),
                         degrade_tick_s=3600.0)   # ticks only via test
    try:
        assert fe.query(*_q()).degraded is False
        _force_level(fe, 2)
        res = fe.query(*_q())
        assert res.degraded is True
        assert stub.calls[-1][1] == 2              # server saw the level
        snap = json.loads(reg.to_json())
        deg = {s["labels"]["level"]: s["value"] for s in
               snap["repro_frontend_degraded_queries_total"]["series"]}
        assert deg == {"2": 1}
    finally:
        fe.close()


def test_l3_sheds_only_lowest_priority_class_and_recovers():
    stub = _StubServer()
    fe = ServingFrontend(
        stub, max_batch=4, batch_window_ms=0.0, queue_depth=8,
        quotas={"gold": TenantQuota(rate_qps=1e6, priority=1),
                "bronze": TenantQuota(rate_qps=1e6, priority=0)},
        registry=MetricsRegistry(),
        degrade=DegradeConfig(dwell_ticks=1), degrade_tick_s=3600.0)
    try:
        _force_level(fe, 3)
        with pytest.raises(Rejected) as exc:
            fe.submit(*_q(), tenant="bronze")
        assert exc.value.reason == "shed"
        assert fe.query(*_q(), tenant="gold").ids.shape == (4,)   # untouched
        # hysteresis recovery: calm ticks walk the ladder back down
        for _ in range(3):
            fe.degrade.tick(burn=0.0, queue_frac=0.0)
        assert fe.degrade.level == 0
        assert fe.query(*_q(), tenant="bronze").ids.shape == (4,)
    finally:
        fe.close()


def test_uniform_priorities_never_shed():
    stub = _StubServer()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=MetricsRegistry(),
                         degrade=DegradeConfig(dwell_ticks=1),
                         degrade_tick_s=3600.0)
    try:
        _force_level(fe, 3)
        # one priority class only: L3 must not black out the whole tenant
        # population, it just keeps L2 behaviour
        res = fe.query(*_q())
        assert res.degraded is True
    finally:
        fe.close()


def test_stub_without_degrade_kwarg_still_serves():
    class _Legacy:
        k = 4

        def query_many(self, qi, qv, ctx=None):      # no degrade param
            B = qi.shape[0]
            ids = np.tile(np.arange(4, dtype=np.int64), (B, 1))
            return QueryResult(ids=ids, scores=np.zeros((B, 4), np.float32),
                               k=4, backend="stub", trace_id="q-stub")

    fe = ServingFrontend(_Legacy(), max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=MetricsRegistry(),
                         degrade=DegradeConfig(dwell_ticks=1),
                         degrade_tick_s=3600.0)
    try:
        _force_level(fe, 2)
        assert fe.query(*_q()).ids.shape == (4,)     # served, undegraded
    finally:
        fe.close()


def test_http_response_carries_degraded_flag():
    stub = _StubServer()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=MetricsRegistry(),
                         degrade=DegradeConfig(dwell_ticks=1),
                         degrade_tick_s=3600.0)
    try:
        with FrontendServer(fe, port=0) as door:
            qi, qv = _q()
            body = json.dumps({"indices": qi.tolist(),
                               "values": qv.tolist()}).encode()

            def post():
                req = urllib.request.Request(door.url + "/v1/query",
                                             data=body, method="POST")
                return json.loads(urllib.request.urlopen(
                    req, timeout=30).read())

            assert post()["degraded"] is False
            _force_level(fe, 1)
            assert post()["degraded"] is True
    finally:
        fe.close()


# ---------------------------------------------------------------------------
# degraded answers on the real engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    idx, val = synth.make_corpus(0, DS, 96, pad=32)
    qi, qv = synth.make_queries(1, DS, 8, pad=16)
    index = SinnamonIndex(EngineSpec(n=DS.n, m=12, capacity=128, max_nnz=32,
                                     h=2, seed=3, value_dtype="float32"))
    index.insert_many(list(range(96)), idx, val)
    return QueryServer(index, k=10, kprime=40), qi, qv


def test_engine_degrade_levels(served):
    server, qi, qv = served
    full = server.query_many(qi, qv)
    l1 = server.query_many(qi, qv, degrade=1)
    l2 = server.query_many(qi, qv, degrade=2)
    assert full.degraded is False
    assert l1.degraded is True and l2.degraded is True
    assert l1.ids.shape == full.ids.shape == l2.ids.shape
    # L1 still reranks: scores are exact inner products, so the top score
    # can only drop when the candidate pool shrinks
    assert np.all(l1.scores[:, 0] <= full.scores[:, 0] + 1e-5)
    # L2 is sketch-only: Theorem 5.1 makes every sketch score an upper
    # bound, so the best sketch score dominates the best exact score
    assert np.all(l2.scores[:, 0] >= full.scores[:, 0] - 1e-4)


def test_engine_degraded_front_door_identity(served):
    """A degraded front-door answer equals the same degrade level asked
    directly — the ladder changes fidelity, never correctness."""
    server, qi, qv = served
    fe = ServingFrontend(server, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8, registry=MetricsRegistry(),
                         degrade=DegradeConfig(dwell_ticks=1),
                         degrade_tick_s=3600.0)
    try:
        fe.query(qi[0], qv[0])            # compile warmup
        _force_level(fe, 2)
        got = fe.query(qi[1], qv[1])
    finally:
        fe.close()
    # reproduce the frontend's exact padded rectangle (max_batch x pad)
    padded_i = np.full((4, 32), -1, np.int32)
    padded_v = np.zeros((4, 32), np.float32)
    L = qi.shape[1]
    padded_i[0, :L], padded_v[0, :L] = qi[1], qv[1]
    expect = server.query_many(padded_i, padded_v, degrade=2)
    np.testing.assert_array_equal(np.asarray(got.ids),
                                  np.asarray(expect.ids)[0])
    np.testing.assert_array_equal(np.asarray(got.scores),
                                  np.asarray(expect.scores)[0])
    assert got.degraded is True
