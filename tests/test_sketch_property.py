"""Property-based sketch tests (optional `hypothesis` dev dep); separate
module so a missing dep degrades to a skip, not a collection error."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep; property tests skip without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import sketch  # noqa: E402

from test_sketch import _random_sparse  # noqa: E402


@given(vals=st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                     min_size=1, max_size=16))
@settings(max_examples=60, deadline=None)
def test_bf16_directed_rounding_property(vals):
    """Directed bf16 rounding preserves bound directions for any floats.

    f32 subnormals are excluded: XLA-CPU flushes them to zero on input, so
    they are indistinguishable from 0 to the engine (hardware FTZ).
    """
    arr = np.array(vals, np.float32)
    arr = np.where(np.abs(arr) < 1.1754944e-38, 0.0, arr)
    x = jnp.asarray(arr)
    up = sketch.quantize_directed(x, "bfloat16", toward_pos_inf=True)
    dn = sketch.quantize_directed(x, "bfloat16", toward_pos_inf=False)
    assert np.all(np.asarray(up, np.float32) >= np.asarray(x))
    assert np.all(np.asarray(dn, np.float32) <= np.asarray(x))


@given(seed=st.integers(0, 2**31 - 1), h=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_upper_bound_property(seed, h):
    """Hypothesis: encode→decode never underestimates (any vector, any h)."""
    gen = np.random.default_rng(seed)
    n, m, pad = 128, 8, 24
    mp = jnp.asarray(sketch.make_mappings(seed % 97, n, m, h))
    idx, val = _random_sparse(gen, n, gen.integers(1, 20), pad)
    u, l = sketch.encode(mp, m, jnp.asarray(idx), jnp.asarray(val))
    ub, lb = sketch.decode_vector(mp, u, l, jnp.asarray(idx))
    keep = idx >= 0
    assert np.all(np.asarray(ub)[keep] >= val[keep])
    assert np.all(np.asarray(lb)[keep] <= val[keep])
