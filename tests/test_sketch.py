"""Sketch unit + property tests (paper §4.1/§5, Theorem 5.1 invariant)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch


def _random_sparse(gen, n, psi, pad):
    idx = np.full(pad, -1, np.int32)
    val = np.zeros(pad, np.float32)
    c = min(psi, pad)
    idx[:c] = np.sort(gen.choice(n, c, replace=False))
    val[:c] = gen.normal(0, 1, c)
    val[:c] = np.where(val[:c] == 0, 1e-6, val[:c])
    return idx, val


@pytest.mark.parametrize("h", [1, 2, 3])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_bounds_invariant(h, dtype, rng):
    """Decoded value is ALWAYS within [lb, ub] — Theorem 5.1's ingredient."""
    n, m, pad = 300, 16, 40
    mp = jnp.asarray(sketch.make_mappings(0, n, m, h))
    for trial in range(20):
        idx, val = _random_sparse(rng, n, 25, pad)
        u, l = sketch.encode(mp, m, jnp.asarray(idx), jnp.asarray(val),
                             dtype=dtype)
        ub, lb = sketch.decode_vector(mp, u, l, jnp.asarray(idx))
        keep = idx >= 0
        assert np.all(np.asarray(ub)[keep] >= val[keep] - 0), \
            (np.asarray(ub)[keep] - val[keep]).min()
        assert np.all(np.asarray(lb)[keep] <= val[keep] + 0)


def test_largest_value_exact(rng):
    """The max value in a vector is always recovered exactly (paper §5.2)."""
    n, m, h, pad = 200, 8, 1, 30
    mp = jnp.asarray(sketch.make_mappings(3, n, m, h))
    for _ in range(10):
        idx, val = _random_sparse(rng, n, 20, pad)
        u, l = sketch.encode(mp, m, jnp.asarray(idx), jnp.asarray(val),
                             dtype="float32")
        ub, _ = sketch.decode_vector(mp, u, l, jnp.asarray(idx))
        j = np.argmax(np.where(idx >= 0, val, -np.inf))
        assert np.asarray(ub)[j] == pytest.approx(val[j], abs=1e-6)


def test_positive_only_sinnamon_plus(rng):
    n, m, pad = 100, 8, 20
    mp = jnp.asarray(sketch.make_mappings(1, n, m, 2))
    idx, val = _random_sparse(rng, n, 15, pad)
    val = np.abs(val)
    u, l = sketch.encode(mp, m, jnp.asarray(idx), jnp.asarray(val),
                         positive_only=True)
    assert l is None
    ub, lb = sketch.decode_vector(mp, u, None, jnp.asarray(idx))
    keep = idx >= 0
    assert np.all(np.asarray(ub)[keep] >= val[keep])
    assert np.all(np.asarray(lb) == 0)


# The hypothesis-based rounding/upper-bound properties live in
# tests/test_sketch_property.py so a missing optional `hypothesis` degrades
# to one skipped module instead of erroring this suite at collection.
