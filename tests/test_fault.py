"""ISSUE 9 unit contracts: the fault-injection + recovery primitives.

* Failpoint spec grammar parses exactly the documented forms and rejects
  the rest; armed sites fire deterministically under a seed, count their
  hits, and publish ``repro_fault_injected_total``.
* ``call_with_retry`` retries transient OSErrors with backoff under a
  deadline budget, never retries ENOSPC, and counts retries.
* ``CircuitBreaker`` walks closed → open → half-open → closed/open with
  exactly one half-open probe.
* ``DegradationController`` escalates immediately when hot, holds level
  in-between, and de-escalates only after the dwell (hysteresis).

All clocks/sleeps/randomness are injected — no wall-clock sleeps here.
"""

import errno
import json

import pytest

from repro.fault import failpoints as fp
from repro.fault.degrade import DegradationController, DegradeConfig
from repro.fault.retry import (CircuitBreaker, RetryPolicy, call_with_retry,
                               fsync_transient, transient_oserror)
from repro.obs import MetricsRegistry


# ---------------------------------------------------------------------------
# failpoint grammar + firing
# ---------------------------------------------------------------------------

def test_spec_grammar_parses_documented_forms():
    reg = fp.FailpointRegistry(seed=0).configure(
        "wal.fsync=error:0.25, snapshot.write=enospc,"
        "wal.write=torn:0.3:0.5, device.dispatch=stall:250ms:0.1,"
        "compact.swap=eio")
    sites = reg.sites()
    assert sites == {
        "wal.fsync": "error:0:0.25",
        "snapshot.write": "enospc:0:1",
        "wal.write": "torn:0.3:0.5",
        "device.dispatch": "stall:250:0.1",
        "compact.swap": "eio:0:1",
    }
    assert reg.active
    reg.clear("wal.fsync")
    assert "wal.fsync" not in reg.sites()
    reg.clear()
    assert not reg.active


@pytest.mark.parametrize("bad", [
    "no_equals_sign",
    "site=unknownmode",
    "site=stall",              # stall needs a duration
    "site=stall:250",          # ...with the ms suffix
    "site=torn:1.5",           # torn fraction must be < 1
    "site=error:0",            # probability must be > 0
    "site=error:1.5",          # ...and <= 1
    "site=error:0.5:extra",    # trailing junk
])
def test_spec_grammar_rejects_malformed(bad):
    with pytest.raises(ValueError):
        fp.FailpointRegistry().configure(bad)


def test_fire_modes_raise_stall_and_tear():
    sleeps = []
    reg = fp.FailpointRegistry(seed=0, registry=MetricsRegistry(),
                               sleep=sleeps.append)
    reg.configure("a=error,b=enospc,c=stall:50ms,d=torn:0.25")
    with pytest.raises(fp.InjectedError) as e:
        reg.fire("a")
    assert e.value.errno == errno.EIO
    assert isinstance(e.value, OSError)          # real error paths catch it
    assert isinstance(e.value, fp.InjectedFault)  # chaos can tell it apart
    with pytest.raises(fp.InjectedError) as e:
        reg.fire("b")
    assert e.value.errno == errno.ENOSPC
    act = reg.fire("c")
    assert act.mode == "stall" and sleeps == [0.05]
    act = reg.fire("d")
    assert act.mode == "torn" and act.arg == 0.25
    assert reg.fire("unarmed.site") is None
    assert reg.hits("a") == reg.hits("b") == reg.hits("c") == 1


def test_probability_is_seeded_and_deterministic():
    def schedule(seed):
        reg = fp.FailpointRegistry(seed=seed, registry=MetricsRegistry())
        reg.configure("x=torn:0.5:0.3")
        return [reg.fire("x") is not None for _ in range(64)]

    a, b = schedule(7), schedule(7)
    assert a == b                          # same seed -> same fault schedule
    assert 0 < sum(a) < 64                 # it actually rolls dice
    assert schedule(8) != a                # different seed -> different run


def test_count_limits_fires_then_disarms():
    reg = fp.FailpointRegistry(registry=MetricsRegistry())
    reg.set("wal.fsync", "error", count=2)
    for _ in range(2):
        with pytest.raises(fp.InjectedError):
            reg.fire("wal.fsync")
    assert reg.fire("wal.fsync") is None   # auto-disarmed
    assert reg.hits("wal.fsync") == 2
    assert not reg.active


def test_fires_publish_injected_total():
    mreg = MetricsRegistry()
    reg = fp.FailpointRegistry(registry=mreg).configure("s=torn")
    reg.fire("s")
    reg.fire("s")
    snap = json.loads(mreg.to_json())
    series = snap["repro_fault_injected_total"]["series"]
    assert [(s["labels"]["site"], s["labels"]["mode"], s["value"])
            for s in series] == [("s", "torn", 2)]


def test_injected_contextmanager_scopes_the_global():
    before = fp.get_failpoints()
    with fp.injected("x.y=error", registry=MetricsRegistry()) as reg:
        assert fp.get_failpoints() is reg
        with pytest.raises(fp.InjectedError):
            fp.fire("x.y")
        assert fp.fire("other") is None
    assert fp.get_failpoints() is before
    assert fp.fire("x.y") is None          # disarmed once scope exits


# ---------------------------------------------------------------------------
# retry with backoff under a deadline budget
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def sleep(self, d):
        self.sleeps.append(d)
        self.t += d


def test_retry_recovers_from_transient_errors():
    clk = _FakeClock()
    mreg = MetricsRegistry()
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError(errno.EIO, "transient")
        return "ok"

    out = call_with_retry(flaky, policy=RetryPolicy(attempts=3),
                          op="t", clock=clk, sleep=clk.sleep,
                          rand=lambda: 0.0, registry=mreg)
    assert out == "ok" and len(calls) == 3
    # full backoff (jitter rand=0 -> no reduction): base, base*mult
    assert clk.sleeps == [0.01, 0.02]
    snap = json.loads(mreg.to_json())
    assert snap["repro_fault_retries_total"]["series"][0]["value"] == 2


def test_retry_exhausts_attempts_and_reraises():
    clk = _FakeClock()

    def broken():
        raise OSError(errno.EIO, "still broken")

    with pytest.raises(OSError):
        call_with_retry(broken, policy=RetryPolicy(attempts=3),
                        clock=clk, sleep=clk.sleep, rand=lambda: 0.0,
                        registry=MetricsRegistry())
    assert len(clk.sleeps) == 2            # attempts-1 backoffs then raise


def test_enospc_is_never_retried():
    calls = []

    def disk_full():
        calls.append(1)
        raise OSError(errno.ENOSPC, "disk full")

    assert not transient_oserror(OSError(errno.ENOSPC, "x"))
    with pytest.raises(OSError) as e:
        call_with_retry(disk_full, policy=RetryPolicy(attempts=5),
                        registry=MetricsRegistry())
    assert e.value.errno == errno.ENOSPC and len(calls) == 1


def test_fsync_transient_retries_interruptions_only():
    """At a durability barrier only pure interruptions are retryable;
    EIO is fatal (fsyncgate: a failed fsync may mark dirty pages clean,
    so a retried "success" proves nothing)."""
    assert fsync_transient(OSError(errno.EINTR, "interrupted"))
    assert fsync_transient(OSError(errno.EAGAIN, "again"))
    assert not fsync_transient(OSError(errno.EIO, "io error"))
    assert not fsync_transient(OSError(errno.ENOSPC, "disk full"))
    assert not fsync_transient(ValueError("not an OSError"))

    calls = []

    def eio_fsync():
        calls.append(1)
        raise OSError(errno.EIO, "lost page writeback")

    with pytest.raises(OSError) as e:
        call_with_retry(eio_fsync, policy=RetryPolicy(attempts=5),
                        should_retry=fsync_transient,
                        registry=MetricsRegistry())
    assert e.value.errno == errno.EIO and len(calls) == 1


def test_retry_respects_deadline_budget():
    clk = _FakeClock()

    def slow_fail():
        clk.t += 0.2                       # each attempt burns 200ms of work
        raise OSError(errno.EIO, "transient")

    with pytest.raises(OSError):
        call_with_retry(slow_fail,
                        policy=RetryPolicy(attempts=10, base_delay_s=0.5,
                                           deadline_s=0.3),
                        clock=clk, sleep=clk.sleep, rand=lambda: 0.0,
                        registry=MetricsRegistry())
    # first attempt ends at t=0.2 (0.1 left): delay clamped to the budget;
    # second attempt ends past the deadline: re-raise with no more sleeps.
    assert clk.sleeps == [pytest.approx(0.1)]


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_closed_open_halfopen_cycle():
    clk = _FakeClock()
    mreg = MetricsRegistry()
    br = CircuitBreaker(failure_threshold=3, reset_timeout_s=10.0,
                        name="t", clock=clk, registry=mreg)
    assert br.state == "closed" and br.allow()
    for _ in range(2):
        br.record_failure()
    assert br.state == "closed"            # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    assert br.remaining_s() == pytest.approx(10.0)
    clk.t = 4.0
    assert not br.allow() and br.remaining_s() == pytest.approx(6.0)
    clk.t = 10.0
    assert br.state == "half_open"
    assert br.allow()                      # the single probe
    assert not br.allow()                  # everyone else keeps fast-failing
    br.record_success()
    assert br.state == "closed" and br.allow()
    snap = json.loads(mreg.to_json())
    assert snap["repro_fault_breaker_open_total"]["series"][0]["value"] == 1
    assert snap["repro_fault_breaker_state"]["series"][0]["value"] == 0.0


def test_breaker_halfopen_failure_reopens():
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        clock=clk, registry=MetricsRegistry())
    br.record_failure()
    clk.t = 5.0
    assert br.allow()                      # half-open probe
    br.record_failure()                    # probe failed
    assert br.state == "open" and not br.allow()
    assert br.remaining_s() == pytest.approx(5.0)   # timer restarted
    assert br.snapshot() == ("open", 2)    # both failures on record


def test_breaker_stale_halfopen_probe_is_reclaimed():
    """A probe holder that never reports an outcome (wedged, or the probed
    request was dropped upstream) must not wedge the breaker: after
    ``probe_timeout_s`` the token is reclaimed for the next caller."""
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        probe_timeout_s=2.0, clock=clk,
                        registry=MetricsRegistry())
    br.record_failure()
    clk.t = 5.0
    assert br.allow()                      # probe granted at t=5...
    assert not br.allow()                  # ...and held
    clk.t = 6.9
    assert not br.allow()                  # still within the probe window
    clk.t = 7.0                            # holder never reported: reclaim
    assert br.allow()
    br.record_success()
    assert br.state == "closed"


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(failure_threshold=2, registry=MetricsRegistry())
    br.record_failure()
    br.record_success()
    br.record_failure()                    # 1 consecutive, not 2
    assert br.state == "closed"


# ---------------------------------------------------------------------------
# degradation ladder hysteresis
# ---------------------------------------------------------------------------

def test_ladder_escalates_hot_holds_between_recovers_after_dwell():
    mreg = MetricsRegistry()
    c = DegradationController(DegradeConfig(dwell_ticks=3), registry=mreg)
    assert c.tick(burn=5.0, queue_frac=0.0) == 1     # burn-hot escalates
    assert c.tick(burn=0.0, queue_frac=0.9) == 2     # queue-hot escalates
    assert c.tick(burn=2.0, queue_frac=0.5) == 2     # in-between holds
    for i in range(2):
        assert c.tick(burn=0.5, queue_frac=0.1) == 2  # calm, inside dwell
    assert c.tick(burn=0.5, queue_frac=0.1) == 1     # 3rd calm tick -> down
    # an in-between reading resets the dwell counter
    c.tick(burn=0.5, queue_frac=0.1)
    c.tick(burn=0.5, queue_frac=0.1)
    assert c.tick(burn=2.0, queue_frac=0.5) == 1     # hold + reset dwell
    for i in range(2):
        assert c.tick(burn=0.5, queue_frac=0.1) == 1
    assert c.tick(burn=0.5, queue_frac=0.1) == 0     # full dwell again
    snap = json.loads(mreg.to_json())
    trans = {s["labels"]["direction"]: s["value"] for s in
             snap["repro_frontend_degraded_transitions_total"]["series"]}
    assert trans == {"up": 2, "down": 2}
    assert snap["repro_frontend_degraded_level"]["series"][0]["value"] == 0.0


def test_ladder_clamps_at_max_level_and_disabled_is_inert():
    c = DegradationController(DegradeConfig(max_level=3),
                              registry=MetricsRegistry())
    for _ in range(6):
        c.tick(burn=100.0, queue_frac=1.0)
    assert c.level == 3
    off = DegradationController(DegradeConfig(enabled=False),
                                registry=MetricsRegistry())
    for _ in range(6):
        assert off.tick(burn=100.0, queue_frac=1.0) == 0
