"""Equivariance + Wigner machinery tests for the eSCN GNN."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import graph as graphdata
from repro.models import gnn, sh

pytestmark = pytest.mark.slow


def _rand_rot(gen):
    A = gen.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q.astype(np.float32)


def test_wigner_represents_rotation(rng):
    """D(R_e) Y(x) == Y(R_e x) and orthogonality, l up to 6."""
    lmax = 6
    v = rng.normal(size=(4, 3)).astype(np.float32)
    blocks = sh.wigner_blocks(lmax, jnp.asarray(v))
    pts = rng.normal(size=(30, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    Y = sh.real_sh_numpy(lmax, pts)
    for e in range(4):
        u = v[e] / np.linalg.norm(v[e])
        th, ph = np.arccos(u[2]), np.arctan2(u[1], u[0])
        Ry = lambda a: np.array([[np.cos(a), 0, np.sin(a)], [0, 1, 0],
                                 [-np.sin(a), 0, np.cos(a)]])
        Rz = lambda a: np.array([[np.cos(a), -np.sin(a), 0],
                                 [np.sin(a), np.cos(a), 0], [0, 0, 1]])
        Rm = Ry(-th) @ Rz(-ph)
        assert np.allclose(Rm @ u, [0, 0, 1], atol=1e-6)
        YR = sh.real_sh_numpy(lmax, pts @ Rm.T)
        for l in range(lmax + 1):
            D = np.asarray(blocks[l][e])
            np.testing.assert_allclose(Y[:, sh.l_slice(l)] @ D.T,
                                       YR[:, sh.l_slice(l)], atol=2e-5)
            np.testing.assert_allclose(D @ D.T, np.eye(2 * l + 1),
                                       atol=2e-5)


def test_wigner_aligns_edge_to_z(rng):
    """D(R_e) Y(ê) = Y(ẑ): all m≠0 components vanish in the edge frame."""
    lmax = 4
    v = rng.normal(size=(8, 3)).astype(np.float32)
    blocks = sh.wigner_blocks(lmax, jnp.asarray(v))
    u = v / np.linalg.norm(v, axis=1, keepdims=True)
    Y = sh.real_sh_numpy(lmax, u)
    Yz = sh.real_sh_numpy(lmax, np.array([[0.0, 0.0, 1.0]]))
    for l in range(lmax + 1):
        got = jnp.einsum("eij,ej->ei", blocks[l],
                         jnp.asarray(Y[:, sh.l_slice(l)].astype(np.float32)))
        np.testing.assert_allclose(np.asarray(got),
                                   np.broadcast_to(Yz[:, sh.l_slice(l)],
                                                   got.shape), atol=1e-5)


@pytest.fixture(scope="module")
def model():
    cfg = gnn.GNNConfig(n_layers=2, c=16, l_max=3, m_max=2, n_heads=4,
                        n_rbf=8, f_in=5, n_out=3, edge_chunk=16)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _graph(gen, N=12, E=32, f_in=5):
    pos = gen.normal(size=(N, 3)).astype(np.float32)
    src = gen.integers(0, N, E).astype(np.int32)
    dst = ((src + gen.integers(1, N, E)) % N).astype(np.int32)
    src[-3:] = -1
    vec = (pos[np.where(src >= 0, src, 0)]
           - pos[np.where(dst >= 0, dst, 0)]).astype(np.float32)
    feat = gen.normal(size=(N, f_in)).astype(np.float32)
    return gnn.GraphBatch(jnp.asarray(feat), jnp.asarray(src),
                          jnp.asarray(dst), jnp.asarray(vec),
                          jnp.zeros(N, jnp.int32),
                          jnp.zeros((N, 3), jnp.float32),
                          jnp.zeros(N, jnp.int32), 1)


def test_model_equivariance(model, rng):
    """Global rotation: invariant l=0 outputs; l=1 rotates with D₁(R)."""
    cfg, params = model
    g = _graph(rng)
    Rm = _rand_rot(rng)
    g_rot = g._replace(edge_vec=jnp.asarray(
        np.asarray(g.edge_vec) @ Rm.T))
    f1 = gnn.forward(params, g, cfg)
    f2 = gnn.forward(params, g_rot, cfg)
    scale = float(jnp.abs(f1).max())
    assert float(jnp.abs(f1[:, 0, :] - f2[:, 0, :]).max()) < 1e-3 * max(
        scale, 1)
    D1 = jnp.asarray(sh.fit_wigner_numpy(1, Rm).astype(np.float32))
    pred = jnp.einsum("ij,njc->nic", D1, f1[:, 1:4, :])
    assert float(jnp.abs(pred - f2[:, 1:4, :]).max()) < 2e-3 * max(scale, 1)


def test_padded_edges_are_inert(model, rng):
    """Changing padded-edge payloads never changes the output."""
    cfg, params = model
    g = _graph(rng)
    f1 = gnn.forward(params, g, cfg)
    vec2 = np.asarray(g.edge_vec).copy()
    vec2[-3:] = 123.0
    f2 = gnn.forward(params, g._replace(edge_vec=jnp.asarray(vec2)), cfg)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-6)


def test_edge_chunking_invariance(model, rng):
    """Streaming segment-softmax: result independent of chunk size."""
    import dataclasses
    cfg, params = model
    g = _graph(rng)
    f1 = gnn.forward(params, g, cfg)
    cfg2 = dataclasses.replace(cfg, edge_chunk=8)
    f2 = gnn.forward(params, g, cfg2)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4,
                               atol=1e-5)


def test_neighbor_sampler(rng):
    n, e = 100, 600
    src = rng.integers(0, n, e)
    dst = (src + rng.integers(1, n, e)) % n
    feats = rng.normal(size=(n, 7)).astype(np.float32)
    labels = rng.integers(0, 5, n).astype(np.int32)
    sampler = graphdata.NeighborSampler(0, n, np.stack([src, dst]),
                                        feats, labels)
    g = sampler.sample(np.arange(8), fanouts=(4, 3), pad_nodes=128,
                       pad_edges=256)
    assert g.node_feat.shape == (128, 7)
    assert g.edge_src.shape == (256,)
    valid = g.edge_src >= 0
    assert valid.sum() > 0
    # sampled edges reference in-range local node ids
    assert g.edge_src[valid].max() < 128 and g.edge_dst[valid].max() < 128
    # seeds carry labels, non-seeds are masked
    assert (g.labels >= 0).sum() <= 8


# The hypothesis-based equivariance property lives in
# tests/test_gnn_property.py (see test_engine_property.py for the rationale).
