"""ISSUE 6: metrics-registry unit contracts (no engine, no JAX).

* thread-safety: concurrent increments/observes lose nothing,
* histogram percentiles track np.percentile within one bucket's width,
* exponential bucket boundaries follow bisect_left (upper-inclusive `le`),
* label cardinality is capped (LabelCardinalityError) without evicting
  existing series,
* Prometheus exposition matches a golden text and round-trips through
  parse_exposition,
* snapshots of identical layouts merge additively,
* NULL_REGISTRY swallows everything.
"""

import json
import threading

import numpy as np
import pytest

from repro.obs import metrics as obs

# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_counter_monotone_and_negative_rejected():
    reg = obs.MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = obs.MetricsRegistry().gauge("g")
    g.set(2.5)
    g.inc(1.5)
    g.dec(4.0)
    assert g.value == 0.0


def test_concurrent_increments_lose_nothing():
    reg = obs.MetricsRegistry()
    c = reg.counter("hits_total", "x")
    h = reg.histogram("lat_ms", "x")
    per_thread, n_threads = 5000, 8

    def work():
        for i in range(per_thread):
            c.inc()
            h.observe(0.1 + (i % 7))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == per_thread * n_threads
    assert h.count == per_thread * n_threads
    assert sum(h.bucket_counts) == per_thread * n_threads


# ---------------------------------------------------------------------------
# histogram buckets & percentiles
# ---------------------------------------------------------------------------


def test_bucket_boundaries_upper_inclusive():
    b = obs.Buckets(1.0, 2.0, 4)                    # bounds 1, 2, 4, 8
    assert b.bounds == (1.0, 2.0, 4.0, 8.0)
    # Prometheus `le` semantics: a sample on the bound lands IN that bucket
    assert [b.index(v) for v in (0.5, 1.0, 1.5, 2.0, 7.9, 8.0, 9.0)] \
        == [0, 0, 1, 1, 3, 3, 4]                   # 4 == +Inf overflow


def test_percentiles_track_numpy_within_bucket_resolution():
    h = obs.Histogram()                            # DEFAULT_LATENCY_BUCKETS
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=1.0, sigma=0.8, size=20000)
    for v in samples:
        h.observe(float(v))
    tol = obs.DEFAULT_LATENCY_BUCKETS.factor * 1.01   # one bucket + slack
    for p in (50, 90, 99):
        exact = np.percentile(samples, p)
        est = h.percentile(p)
        assert exact / tol <= est <= exact * tol, (p, est, exact)


def test_percentile_edge_cases():
    h = obs.Histogram(obs.Buckets(1.0, 2.0, 4))
    assert h.percentile(50) == 0.0                 # empty -> 0, not NaN
    h.observe(3.0, n=10)
    # single distinct value: every percentile clamps to the tracked min/max
    assert h.percentile(50) == 3.0
    assert h.percentile(99) == 3.0
    snap = h.snapshot()
    assert snap["min"] == 3.0 and snap["max"] == 3.0 and snap["count"] == 10


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_label_cardinality_cap_preserves_existing_series():
    reg = obs.MetricsRegistry(max_label_sets=4)
    for i in range(4):
        reg.counter("c_total", "x", labels={"shard": str(i)}).inc()
    with pytest.raises(obs.LabelCardinalityError):
        reg.counter("c_total", "x", labels={"shard": "overflow"})
    # pre-existing series still addressable and intact after the refusal
    assert reg.counter("c_total", "x", labels={"shard": "2"}).value == 1


def test_type_and_bucket_conflicts_rejected():
    reg = obs.MetricsRegistry()
    reg.counter("m", "x")
    with pytest.raises(ValueError):
        reg.gauge("m")
    reg.histogram("h_ms", "x", buckets=obs.Buckets(1.0, 2.0, 4))
    with pytest.raises(ValueError):
        reg.histogram("h_ms", "x", buckets=obs.Buckets(1.0, 4.0, 4))
    with pytest.raises(ValueError):
        reg.counter("bad name!", "x")


def test_collector_removal_and_error_isolation():
    reg = obs.MetricsRegistry()
    calls = {"n": 0}

    def once():
        calls["n"] += 1
        reg.gauge("live").set(7)
        return False                                # ask to be removed

    def broken():
        raise RuntimeError("boom")

    reg.add_collector(once)
    reg.add_collector(broken)
    reg.collect()
    reg.collect()
    assert calls["n"] == 1                          # removed after False
    assert reg.gauge("live").value == 7
    # broken collector is counted+kept, and never poisons a scrape
    assert reg.collector_errors == 2
    assert "live 7" in reg.exposition()


# ---------------------------------------------------------------------------
# exposition / parse / merge
# ---------------------------------------------------------------------------

GOLDEN = """\
# HELP demo_total Things.
# TYPE demo_total counter
demo_total{kind="a"} 3
# TYPE demo_gauge gauge
demo_gauge 2.5
# TYPE demo_ms histogram
demo_ms_bucket{le="1"} 1
demo_ms_bucket{le="2"} 1
demo_ms_bucket{le="+Inf"} 2
demo_ms_sum 3.5
demo_ms_count 2
"""


def _demo_registry():
    reg = obs.MetricsRegistry()
    reg.counter("demo_total", "Things.", labels={"kind": "a"}).inc(3)
    reg.gauge("demo_gauge").set(2.5)
    h = reg.histogram("demo_ms", buckets=obs.Buckets(1, 2, 2))
    h.observe(0.5)
    h.observe(3.0)
    return reg


def test_exposition_golden():
    assert _demo_registry().exposition() == GOLDEN


def test_exposition_parse_round_trip():
    flat = obs.parse_exposition(_demo_registry().exposition())
    assert flat[("demo_total", (("kind", "a"),))] == 3.0
    assert flat[("demo_gauge", ())] == 2.5
    assert flat[("demo_ms_bucket", (("le", "+Inf"),))] == 2.0
    assert flat[("demo_ms_count", ())] == 2.0


def test_parse_rejects_malformed():
    with pytest.raises(ValueError):
        obs.parse_exposition("demo_total{kind=a} 3")   # unquoted label
    with pytest.raises(ValueError):
        obs.parse_exposition("demo_total three")


def test_to_json_is_valid_json():
    doc = json.loads(_demo_registry().to_json())
    assert doc["demo_ms"]["type"] == "histogram"
    assert doc["demo_total"]["series"][0]["value"] == 3


def test_merge_snapshots_additive():
    a, b = _demo_registry().snapshot(), _demo_registry().snapshot()
    merged = obs.merge_snapshots(a, b)
    assert merged["demo_total"]["series"][0]["value"] == 6
    hist = merged["demo_ms"]["series"][0]
    assert hist["count"] == 4 and hist["sum"] == 7.0
    # layout mismatch must refuse, not silently mis-bin
    reg2 = obs.MetricsRegistry()
    reg2.histogram("demo_ms", buckets=obs.Buckets(1, 4, 2)).observe(1.0)
    with pytest.raises(ValueError):
        obs.merge_snapshots(a, reg2.snapshot())


def test_null_registry_is_inert():
    n = obs.NULL_REGISTRY
    n.counter("x_total", "h", labels={"a": "b"}).inc(5)
    n.gauge("g").set(1.0)
    n.histogram("h_ms").observe(2.0)
    n.add_collector(lambda r: True)
    assert n.exposition() == ""
    assert n.to_json() == "{}"


def test_set_registry_swaps_global():
    fresh = obs.MetricsRegistry()
    old = obs.set_registry(fresh)
    try:
        assert obs.get_registry() is fresh
    finally:
        obs.set_registry(old)
    assert obs.get_registry() is old
