"""ISSUE 6 tentpole contracts: telemetry threaded through the live system.

* Sampled staged tracing decomposes a served query into the
  admission -> sketch_scan -> topk_merge -> rerank stages whose spans sum to
  (almost all of) the measured batch time — and returns results identical
  to the fused path, for every scoring backend.
* A churn-then-query stream over a durable index populates the WAL,
  snapshot, drift and recovery surfaces of one injected registry.
* The /metrics endpoint serves a parseable Prometheus exposition of all of
  the above; the event log captures traced queries as JSONL.
* The sharded index traces as admission -> spmd_search.
* BackgroundCompactor outcomes land in ``repro_compactor_outcomes_total``.
"""

import json
import time
import urllib.request

import numpy as np
import pytest

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.obs import EventLog, MetricsRegistry, MetricsServer
from repro.obs import metrics as obs_metrics
from repro.obs.metrics import parse_exposition
from repro.persist import compact as compactlib
from repro.persist.durable import DurableSinnamonIndex
from repro.serving.serve import QUERY_STAGES, QueryServer
from repro.serving.sharded import ShardedSinnamonIndex

DS = synth.SparseDatasetSpec("t", n=400, psi_doc=20, psi_query=10,
                             value_dist="gaussian")
N_DOCS = 96


def _spec(capacity=128):
    return EngineSpec(n=DS.n, m=12, capacity=capacity, max_nnz=32, h=2,
                      seed=3, value_dtype="float32")


def _churn(index, idx, val):
    """Insert / delete / re-insert so recycled columns carry real drift."""
    index.insert_many(list(range(64)), idx[:64], val[:64])
    for e in (3, 17, 40, 41):
        index.delete(e)
    index.insert_many(list(range(64, N_DOCS)), idx[64:N_DOCS],
                      val[64:N_DOCS])


@pytest.fixture(scope="module")
def corpus():
    idx, val = synth.make_corpus(0, DS, N_DOCS, pad=32)
    qi, qv = synth.make_queries(1, DS, 8, pad=16)
    return idx, val, qi, qv


@pytest.fixture(scope="module")
def index(corpus):
    idx, val, _, _ = corpus
    index = SinnamonIndex(_spec())
    _churn(index, idx, val)
    return index


# ---------------------------------------------------------------------------
# staged tracing on the single-device query path
# ---------------------------------------------------------------------------

def test_traced_query_spans_cover_measured_time(corpus, index):
    _, _, qi, qv = corpus
    reg = MetricsRegistry()
    srv = QueryServer(index, k=5, kprime=32, registry=reg, trace_every=1)
    srv.query_many(qi, qv)                 # staged-path compile warmup
    t0 = time.perf_counter()
    srv.query_many(qi, qv)
    dt_ms = (time.perf_counter() - t0) * 1e3
    trace = srv.last_trace
    assert trace is not None
    assert tuple(s.name for s in trace.spans) == QUERY_STAGES
    # spans are nested inside the measured window, and the device syncs
    # between spans mean they account for nearly all of it
    assert trace.total_ms() <= dt_ms * 1.02
    assert trace.total_ms() >= 0.5 * dt_ms
    for stage in QUERY_STAGES:
        h = reg.histogram("repro_query_stage_ms",
                          labels={"stage": stage,
                                  "backend": srv._backend_label()})
        assert h.count == 2, stage
    assert reg.counter("repro_query_traces_total").value == 2


def test_traced_path_matches_fused_results_per_backend(corpus, index):
    _, _, qi, qv = corpus
    for backend in ("reference", "grouped", "pallas"):
        reg = MetricsRegistry()
        srv = QueryServer(index, k=5, kprime=32, registry=reg,
                          trace_every=1, score_backend=backend)
        ids_t, sc_t = srv.query_many(qi, qv)
        assert srv.last_trace is not None, backend
        ids_f, sc_f = index.search_many(qi, qv, k=5, kprime=32,
                                        backend=backend)
        np.testing.assert_array_equal(ids_t, ids_f)
        np.testing.assert_allclose(sc_t, sc_f, rtol=1e-6)
        h = reg.histogram("repro_query_stage_ms",
                          labels={"stage": "sketch_scan", "backend": backend})
        assert h.count == 1, backend


def test_untraced_batches_skip_staging(corpus, index):
    _, _, qi, qv = corpus
    reg = MetricsRegistry()
    srv = QueryServer(index, k=5, kprime=32, registry=reg, trace_every=3)
    for _ in range(6):
        srv.query_many(qi, qv)
    assert reg.counter("repro_query_traces_total").value == 2   # 2 of 6
    assert srv.stats["queries"] == 48
    b = srv._backend_label()
    assert reg.histogram("repro_query_latency_ms",
                         labels={"backend": b}).count == 48
    assert reg.counter("repro_queries_total", labels={"backend": b}).value \
        == 48


def test_sharded_trace_stages(corpus):
    idx, val, qi, qv = corpus
    mesh = meshlib.single_device_mesh(("data", "model"))
    sharded = ShardedSinnamonIndex(_spec(), mesh)
    _churn(sharded, idx, val)
    reg = MetricsRegistry()
    srv = QueryServer(sharded, k=5, kprime=32, registry=reg, trace_every=1)
    ids_t, sc_t = srv.query_many(qi, qv)
    assert tuple(s.name for s in srv.last_trace.spans) \
        == ("admission", "spmd_search")
    ids_f, sc_f = sharded.search_many(qi, qv, k=5, kprime=32)
    np.testing.assert_array_equal(ids_t, ids_f)


# ---------------------------------------------------------------------------
# engine gauges, event log, HTTP endpoint
# ---------------------------------------------------------------------------

def test_engine_gauges_reflect_live_index(corpus, index):
    _, _, qi, qv = corpus
    reg = MetricsRegistry()
    QueryServer(index, k=5, kprime=32, registry=reg).query_many(qi, qv)
    snap = reg.snapshot()                  # runs the collector
    lbl = {"index": "index"}               # install_engine_gauges name label
    assert reg.gauge("repro_engine_live_docs", labels=lbl).value == 92
    assert reg.gauge("repro_engine_capacity_slots", labels=lbl).value == 128
    comps = {s["labels"]["component"]: s["value"]
             for s in snap["repro_engine_bytes"]["series"]}
    assert set(comps) == {"sketch", "inverted_index", "storage"}
    assert all(v > 0 for v in comps.values())
    assert reg.gauge("repro_engine_dirty_columns", labels=lbl).value >= 4


def test_event_log_captures_traced_queries(tmp_path, corpus, index):
    _, _, qi, qv = corpus
    path = str(tmp_path / "events.jsonl")
    with EventLog(path) as log:
        srv = QueryServer(index, k=5, kprime=32, registry=MetricsRegistry(),
                          event_log=log, trace_every=2)
        for _ in range(4):
            srv.query_many(qi, qv)
    with open(path) as f:
        events = [json.loads(line) for line in f]
    queries = [e for e in events if e["event"] == "query"]
    assert len(queries) == 4
    traced = [e for e in queries if e.get("spans")]
    assert len(traced) == 2
    assert [s["stage"] for s in traced[0]["spans"]] == list(QUERY_STAGES)
    assert all("ts" in e and e["level"] == "INFO" for e in queries)


def test_metrics_http_endpoint_serves_parseable_exposition(corpus, index):
    _, _, qi, qv = corpus
    reg = MetricsRegistry()
    srv = QueryServer(index, k=5, kprime=32, registry=reg, trace_every=1)
    srv.query_many(qi, qv)
    with MetricsServer(registry=reg, port=0) as ms:
        with urllib.request.urlopen(ms.url + "/metrics", timeout=10) as r:
            assert "text/plain" in r.headers["Content-Type"]
            text = r.read().decode()
        with urllib.request.urlopen(ms.url + "/metrics.json",
                                    timeout=10) as r:
            doc = json.loads(r.read().decode())
        with urllib.request.urlopen(ms.url + "/healthz", timeout=10) as r:
            assert r.read() == b"ok\n"
    flat = parse_exposition(text)          # raises on malformed lines
    names = {name for name, _ in flat}
    for required in ("repro_query_latency_ms_count",
                     "repro_query_stage_ms_count", "repro_engine_live_docs",
                     "repro_engine_bytes"):
        assert required in names, required
    assert doc["repro_query_latency_ms"]["type"] == "histogram"


# ---------------------------------------------------------------------------
# durable churn-then-query: WAL / snapshot / drift / recovery surfaces
# ---------------------------------------------------------------------------

def test_durable_churn_populates_persistence_metrics(tmp_path, corpus):
    idx, val, qi, qv = corpus
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    reg = MetricsRegistry()
    old = obs_metrics.set_registry(reg)    # WAL/snapshot bind to the global
    try:
        live = DurableSinnamonIndex.open(_spec(), wal_dir=wd,
                                         snapshot_dir=sd)
        _churn(live, idx, val)
        live.snapshot()

        # write path: engine op counters + WAL record accounting
        assert reg.counter("repro_engine_ops_total",
                           labels={"op": "insert_many"}).value == 2
        assert reg.counter("repro_engine_ops_total",
                           labels={"op": "delete"}).value == 4
        assert reg.counter("repro_wal_records_total",
                           labels={"kind": "insert"}).value == 2
        assert reg.counter("repro_wal_records_total",
                           labels={"kind": "delete"}).value == 4
        assert reg.counter("repro_wal_appended_bytes_total").value > 0
        assert reg.histogram("repro_wal_append_ms").count >= 6
        assert reg.histogram("repro_wal_fsync_ms").count >= 6

        # snapshot surface
        assert reg.counter("repro_snapshots_total",
                           labels={"outcome": "written"}).value >= 1
        assert reg.histogram("repro_snapshot_ms").count >= 1

        # drift surface: recycled slots under churn carry stale maxima
        drift = compactlib.drift_metrics(live, reg)
        assert reg.gauge("repro_sketch_drift_max").value \
            == drift["max_overestimate"]
        assert reg.gauge("repro_sketch_dirty_active_slots").value \
            == drift["dirty_active"] >= 1

        # queries still served; engine gauges see WAL/snapshot sidecars
        QueryServer(live, k=5, kprime=32, registry=reg).query_many(qi, qv)
        snap = reg.snapshot()
        assert ("repro_wal_last_lsn" in snap
                and "repro_snapshot_age_s" in snap)

        # recovery surface: reopen replays the tail past the snapshot
        rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd,
                                        snapshot_dir=sd)
        assert reg.counter("repro_recoveries_total").value >= 2
        assert reg.gauge("repro_recovery_replay_ms").value >= 0
        np.testing.assert_array_equal(np.asarray(rec.state.active),
                                      np.asarray(live.state.active))
    finally:
        obs_metrics.set_registry(old)


def test_background_compactor_outcomes(tmp_path, corpus):
    idx, val, _, _ = corpus
    wd = str(tmp_path / "wal")
    reg = MetricsRegistry()
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd)
    _churn(live, idx, val)
    assert compactlib.drift_metrics(live, reg)["max_overestimate"] > 0
    comp = compactlib.BackgroundCompactor(live, threshold=0.0,
                                          interval_s=0.02,
                                          registry=reg).start()
    try:
        deadline = time.time() + 30
        while comp.compactions == 0 and time.time() < deadline:
            time.sleep(0.05)
    finally:
        comp.stop()
    assert comp.compactions >= 1
    assert reg.counter("repro_compactor_outcomes_total",
                       labels={"outcome": "compacted"}).value >= 1
    # a quiesced compaction restores the zero-drift invariant
    assert reg.gauge("repro_compaction_drift_after").value == 0.0
    assert reg.histogram("repro_compaction_ms").count >= 1
    assert compactlib.drift_metrics(live, reg)["max_overestimate"] == 0.0


def test_maybe_compact_publishes_before_after(corpus):
    idx, val, _, _ = corpus
    reg = MetricsRegistry()
    index = SinnamonIndex(_spec())
    _churn(index, idx, val)
    pre = compactlib.maybe_compact(index, threshold=0.0, registry=reg)
    assert pre is not None and pre["max_overestimate"] > 0
    assert reg.gauge("repro_compaction_drift_before").value \
        == pre["max_overestimate"]
    assert reg.gauge("repro_compaction_drift_after").value == 0.0
