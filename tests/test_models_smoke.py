"""Per-architecture smoke tests (deliverable f): reduced configs of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import graph as graphdata
from repro.data import loaders
from repro.models import gnn, recsys, transformer as tr

pytestmark = pytest.mark.slow

LM_ARCHS = ["deepseek-67b", "stablelm-12b", "gemma3-27b",
            "llama4-scout-17b-a16e", "moonshot-v1-16b-a3b"]
RS_ARCHS = ["sasrec", "mind", "din", "dlrm-rm2"]


def _finite(x):
    return bool(jnp.all(jnp.isfinite(x)))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    mod = registry.get(arch)
    cfg = mod.smoke_config()
    key = jax.random.PRNGKey(0)
    params = tr.init_params(key, cfg)
    toks, labels = loaders.lm_batch(0, 0, batch=2, seq=32, vocab=cfg.vocab)
    loss, metrics = jax.jit(
        lambda p: tr.lm_loss(p, jnp.asarray(toks), jnp.asarray(labels), cfg)
    )(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: tr.lm_loss(
        p, jnp.asarray(toks), jnp.asarray(labels), cfg)[0])(params)
    gn = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(g.astype(jnp.float32) ** 2)), grads,
        0.0)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    mod = registry.get(arch)
    cfg = mod.smoke_config()
    params = tr.init_params(jax.random.PRNGKey(1), cfg)
    cache = tr.init_cache(cfg, 2, 16)
    logits, cache2 = jax.jit(
        lambda p, c, t: tr.decode_step(p, c, t, 3, cfg)
    )(params, cache, jnp.ones((2, 1), jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert _finite(logits)
    assert cache2["k"].shape == cache["k"].shape


@pytest.mark.parametrize("arch", RS_ARCHS)
def test_recsys_smoke(arch):
    mod = registry.get(arch)
    cfg = mod.smoke_config()
    params = recsys.init_params(jax.random.PRNGKey(2), cfg)
    batch = loaders.recsys_batch(0, 0, batch=8, cfg=cfg)
    batch = jax.tree.map(jnp.asarray, batch)
    loss = jax.jit(lambda p, b: recsys.loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    s = recsys.score(params, batch, cfg)
    assert s.shape == (8,) and _finite(s)
    r = recsys.retrieval_scores(params, batch, cfg)
    assert r.shape == (8, cfg.n_items) and _finite(r)


def test_gnn_smoke_node_class():
    mod = registry.get("equiformer-v2")
    cfg = mod.smoke_config()
    g = graphdata.random_geometric_graph(0, n_nodes=24, n_edges=64,
                                         d_feat=cfg.f_in,
                                         n_classes=cfg.n_out)
    g = jax.tree.map(lambda x: jnp.asarray(x) if isinstance(
        x, np.ndarray) else x, g)
    params = gnn.init_params(jax.random.PRNGKey(3), cfg)
    loss, _ = jax.jit(lambda p, gg: gnn.loss_fn(p, gg, cfg))(params, g)
    assert np.isfinite(float(loss))
    logits = gnn.predict(params, g, cfg)
    assert logits.shape == (24, cfg.n_out) and _finite(logits)


def test_gnn_smoke_energy_force():
    import dataclasses
    mod = registry.get("equiformer-v2")
    cfg = dataclasses.replace(mod.smoke_config(), task="energy_force",
                              n_out=1, f_in=16)
    g = graphdata.molecule_batch(1, batch=4, nodes_per=6, edges_per=10,
                                 d_feat=16)
    g = jax.tree.map(lambda x: jnp.asarray(x) if isinstance(
        x, np.ndarray) else x, g)
    params = gnn.init_params(jax.random.PRNGKey(4), cfg)
    # close over g: n_graphs is static (segment_sum num_segments)
    loss, m = jax.jit(lambda p: gnn.loss_fn(p, g, cfg))(params)
    assert np.isfinite(float(loss))
    energy, forces = gnn.predict(params, g, cfg)
    assert energy.shape == (4,) and forces.shape == (24, 3)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    c = registry.get("deepseek-67b").full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (95, 8192, 64, 8, 22016, 102400)
    c = registry.get("stablelm-12b").full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 5120, 32, 8, 13824, 100352)
    c = registry.get("gemma3-27b").full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.local_global_ratio) == (62, 5376, 32, 16, 21504, 262144, 5)
    c = registry.get("llama4-scout-17b-a16e").full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.n_experts, c.moe_top_k) == (48, 5120, 40, 8, 8192, 202048, 16, 1)
    c = registry.get("moonshot-v1-16b-a3b").full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.n_experts, c.moe_top_k) == (48, 2048, 16, 16, 1408, 163840,
                                          64, 6)
    c = registry.get("equiformer-v2").full_config()
    assert (c.n_layers, c.c, c.l_max, c.m_max, c.n_heads) == (12, 128, 6, 2, 8)
    c = registry.get("sasrec").full_config()
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (50, 2, 1, 50)
    c = registry.get("mind").full_config()
    assert (c.embed_dim, c.n_interests, c.capsule_iters) == (64, 4, 3)
    c = registry.get("din").full_config()
    assert (c.embed_dim, c.seq_len, c.attn_mlp, c.mlp) == (
        18, 100, (80, 40), (200, 80))
    c = registry.get("dlrm-rm2").full_config()
    assert (c.n_dense, c.n_sparse, c.embed_dim, c.bot_mlp, c.top_mlp) == (
        13, 26, 64, (512, 256, 64), (512, 512, 256, 1))


def test_all_cells_enumerate_40():
    cells = list(registry.all_cells())
    assert len(cells) == 40
