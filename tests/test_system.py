"""End-to-end behaviour tests for the paper's system: corpus → streaming
index → batched serving → recall, plus the anytime-budget latency lever."""

import numpy as np
import pytest

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.core.linscan import brute_force_topk
from repro.data import synth
from repro.serving.serve import QueryServer


@pytest.fixture(scope="module")
def served():
    ds = synth.SPLADE_LIKE
    idx, val = synth.make_corpus(0, ds, 2_000, pad=256)
    qi, qv = synth.make_queries(1, ds, 8, pad=96)
    spec = EngineSpec(n=ds.n, m=60, capacity=2_016, max_nnz=256, h=1,
                      positive_only=True)
    index = SinnamonIndex(spec)
    index.insert_many(list(range(2_000)), idx, val)
    return ds, idx, val, qi, qv, index


def test_end_to_end_recall(served):
    ds, idx, val, qi, qv, index = served
    server = QueryServer(index, k=10, kprime=400)
    recalls = []
    for b in range(8):
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, 10)
        ids, _ = server.query(qi[b], qv[b])
        recalls.append(len(set(ids.tolist()) & set(ids0.tolist())) / 10)
    assert np.mean(recalls) >= 0.9
    assert server.latency_percentiles()["p50"] > 0


def test_anytime_budget_is_latency_lever(served):
    """Budgeted scoring touches fewer coordinates — the anytime semantics."""
    ds, idx, val, qi, qv, index = served
    full = QueryServer(index, k=10, kprime=400, budget=None)
    tight = QueryServer(index, k=10, kprime=400, budget=4)
    r_full, r_tight = [], []
    for b in range(8):
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, 10)
        f, _ = full.query(qi[b], qv[b])
        t, _ = tight.query(qi[b], qv[b])
        r_full.append(len(set(f.tolist()) & set(ids0.tolist())) / 10)
        r_tight.append(len(set(t.tolist()) & set(ids0.tolist())) / 10)
    assert np.mean(r_full) >= np.mean(r_tight) - 1e-9


def test_hashed_bucket_index_upper_bound(served):
    """§4.1.2 approximate inverted index: bucketed membership is a superset,
    so Theorem 5.1's upper-bound property survives (DESIGN.md §6)."""
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.storage import vecstore
    ds, idx, val, qi, qv, _ = served
    spec = EngineSpec(n=ds.n, m=30, capacity=512, max_nnz=256, h=1,
                      positive_only=True, index_buckets=512)
    index = SinnamonIndex(spec)
    index.insert_many(list(range(512)), idx[:512], val[:512])
    for b in range(4):
        s = eng.score(index.state, index.spec, jnp.asarray(qi[b]),
                      jnp.asarray(qv[b]))
        qd = vecstore.densify_query(ds.n, jnp.asarray(qi[b]),
                                    jnp.asarray(qv[b]))
        exact = vecstore.exact_scores_all(index.state.store, qd)
        gap = np.asarray(s) - np.asarray(exact)
        assert gap[np.asarray(index.state.active)].min() >= -1e-4
    # and memory shrinks vs the exact bitmap
    assert index.memory_bytes()["inverted_index"] == 512 * (512 // 32) * 4
