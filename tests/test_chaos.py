"""ISSUE 9 chaos contracts: injected storage faults never lose acked writes.

* Torn-write invariant, per record kind: a ``wal.write`` fault injected
  mid-append (torn prefix, EIO, ENOSPC, fsync ENOSPC) leaves the segment
  byte-identical to its pre-append state — no decodable partial record,
  no torn tail — and the writer keeps appending once the fault clears.
* EIO at the fsync barrier is fatal, never retried (fsyncgate: a failed
  fsync can mark dirty pages clean, so a retried "success" proves
  nothing): the append unwinds exactly and the segment is abandoned.
* Multi-shard batches stay all-or-nothing ON DISK: when the second of a
  batch's per-shard appends fails, the first (already durable) record is
  unappended, every partition returns to its pre-batch byte length, and
  replay sees only whole batches (subprocess: forced 2-device host).
* Seeded crash/recover schedules: random op streams with probabilistic
  failpoints armed; ops that raised were never acked and must not
  mutate the live index; recovery after the "crash" must reproduce the
  live (acked-only) index byte-for-byte — zero acked-write loss.

The same invariants run at larger scale in ``benchmarks/chaos.py``.
"""

import os
import random
import subprocess
import sys
import textwrap
from contextlib import contextmanager

import jax
import numpy as np
import pytest

from repro.core.engine import EngineSpec
from repro.data import synth
from repro.fault import failpoints as fp
from repro.obs import MetricsRegistry
from repro.persist import wal
from repro.persist.durable import DurableSinnamonIndex

DS = synth.SparseDatasetSpec("t", n=300, psi_doc=16, psi_query=8,
                             value_dist="gaussian")


def _spec(capacity=96):
    return EngineSpec(n=DS.n, m=12, capacity=capacity, max_nnz=32, h=2,
                      seed=3, value_dtype="float32")


def _assert_state_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


@contextmanager
def _installed(reg):
    """Make ``reg`` the process-global failpoint registry for the scope."""
    prev = fp.set_failpoints(reg)
    try:
        yield reg
    finally:
        fp.set_failpoints(prev)


def _partition_bytes(part_dir):
    return {name: os.path.getsize(os.path.join(part_dir, name))
            for name in sorted(os.listdir(part_dir))}


def _arrays(kind):
    """A representative payload for each WAL record kind."""
    if kind == wal.KIND_INSERT:
        return {"ext_ids": np.arange(4, dtype=np.int64),
                "idx": np.full((4, 8), -1, np.int32),
                "val": np.zeros((4, 8), np.float32)}
    if kind == wal.KIND_INSERT_ONE:
        return {"ext_ids": np.asarray([7], np.int64),
                "idx": np.full((1, 8), -1, np.int32),
                "val": np.ones((1, 8), np.float32)}
    if kind == wal.KIND_DELETE:
        return {"ext_ids": np.asarray([1, 2], np.int64)}
    if kind == wal.KIND_GROW:
        return {"capacity": np.asarray(128, np.int64)}
    return {}                                              # KIND_COMPACT


# ---------------------------------------------------------------------------
# torn-write invariants, per record kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(wal.KIND_NAMES),
                         ids=lambda k: wal.KIND_NAMES[k])
def test_torn_write_never_leaves_decodable_partial(tmp_path, kind):
    """Tear the record at several fractions — including mid-header and
    mid-payload cuts — and require an exact byte-level rollback."""
    w = wal.writer_for(str(tmp_path), 0)
    w.append(kind, _arrays(kind))                          # one good record
    part = os.path.join(str(tmp_path), wal.partition_name(0))
    base_recs, _ = wal.scan_partition(part)
    base_bytes = _partition_bytes(part)

    for frac in (0.01, 0.3, 0.5, 0.9, 0.99):
        reg = fp.FailpointRegistry(registry=MetricsRegistry())
        reg.set("wal.write", "torn", arg=frac, count=1)
        with _installed(reg):
            with pytest.raises(OSError):
                w.append(kind, _arrays(kind))
        assert reg.hits("wal.write") == 1                  # fault landed
        recs, torn = wal.scan_partition(part)
        assert recs == base_recs                   # no new decodable record
        assert not torn                            # no garbage tail either
        assert _partition_bytes(part) == base_bytes    # exact byte rollback

    # faults cleared: the writer resumes at the SAME lsn, no gap
    lsn = w.append(kind, _arrays(kind))
    recs, torn = wal.scan_partition(part)
    assert [r[0] for r in recs] == [lsn - 1, lsn] and not torn
    w.close()


@pytest.mark.parametrize("site,mode", [
    ("wal.write", "error"),        # write fails before any byte lands
    ("wal.write", "enospc"),       # disk full at the write
    ("wal.fsync", "enospc"),       # record fully written, then fsync ENOSPC
])
def test_injected_append_failure_unwinds_exactly(tmp_path, site, mode):
    w = wal.writer_for(str(tmp_path), 0)
    w.append(wal.KIND_INSERT, _arrays(wal.KIND_INSERT))
    part = os.path.join(str(tmp_path), wal.partition_name(0))
    base_recs, _ = wal.scan_partition(part)
    base_bytes = _partition_bytes(part)

    reg = fp.FailpointRegistry(registry=MetricsRegistry())
    reg.set(site, mode, count=1)
    with _installed(reg):
        with pytest.raises(OSError):
            w.append(wal.KIND_INSERT, _arrays(wal.KIND_INSERT))
    # the fsync case is the sharp one: the record bytes DID reach the file
    # and must be truncated away, else replay acks a write that never
    # finished its durability barrier.
    recs, torn = wal.scan_partition(part)
    assert recs == base_recs and not torn
    assert _partition_bytes(part) == base_bytes
    assert w.append(wal.KIND_COMPACT, {}) == base_recs[-1][0] + 1
    w.close()


def test_fsync_eio_is_fatal_not_retried(tmp_path):
    """EIO at the fsync barrier is fatal (fsyncgate: on Linux a failed
    fsync clears the error and marks dirty pages clean, so a retried
    fsync can "succeed" without the bytes being durable): the append must
    unwind exactly, never be acked, and never be retried — then resume
    cleanly on a fresh segment once the fault clears."""
    w = wal.writer_for(str(tmp_path), 0)
    w.append(wal.KIND_INSERT, _arrays(wal.KIND_INSERT))
    part = os.path.join(str(tmp_path), wal.partition_name(0))
    base_recs, _ = wal.scan_partition(part)
    base_bytes = _partition_bytes(part)

    reg = fp.FailpointRegistry(registry=MetricsRegistry())
    reg.set("wal.fsync", "error", count=3)     # would survive any retries
    with _installed(reg):
        with pytest.raises(OSError):
            w.append(wal.KIND_INSERT, _arrays(wal.KIND_INSERT))
    assert reg.hits("wal.fsync") == 1          # fired once: NO retry
    recs, torn = wal.scan_partition(part)
    assert recs == base_recs and not torn      # unwound, never acked
    assert _partition_bytes(part) == base_bytes

    # fault cleared: the writer resumes at the SAME lsn on a fresh segment
    # (the suspect fd was abandoned), and replay sees a gap-free stream.
    lsn = w.append(wal.KIND_INSERT, _arrays(wal.KIND_INSERT))
    assert lsn == base_recs[-1][0] + 1
    recs, torn = wal.scan_partition(part)
    assert [r[0] for r in recs] == [lsn - 1, lsn] and not torn
    assert len(wal._segments(part)) == 2       # abandoned + fresh segment
    w.close()


# ---------------------------------------------------------------------------
# durable index: a failed op is not acked and must not mutate anything
# ---------------------------------------------------------------------------

def test_durable_index_fault_leaves_state_untouched(tmp_path):
    idx, val = synth.make_corpus(3, DS, 64, pad=32)
    live = DurableSinnamonIndex.open(_spec(), wal_dir=str(tmp_path / "wal"))
    live.insert_many(list(range(32)), idx[:32], val[:32])
    ids_before = dict(live._id2slot)
    state_before = live.state
    lsn_before = live._next_lsn

    reg = fp.FailpointRegistry(registry=MetricsRegistry())
    reg.set("wal.write", "torn", arg=0.6, count=1)
    with _installed(reg):
        with pytest.raises(OSError):
            live.insert_many([100], idx[32:33], val[32:33])
    assert live._id2slot == ids_before          # nothing applied in memory
    assert live.state is state_before
    assert live._next_lsn == lsn_before         # lsn not burned

    # the caller's retry (fault cleared) succeeds, and recovery equals the
    # live index: the failed attempt left no trace on disk either.
    live.insert_many([100], idx[32:33], val[32:33])
    rec = DurableSinnamonIndex.open(_spec(), wal_dir=str(tmp_path / "wal"))
    assert rec._id2slot == live._id2slot
    assert rec._free == live._free
    _assert_state_equal(rec.state, live.state)


# ---------------------------------------------------------------------------
# seeded crash/recover schedules — zero acked-write loss
# ---------------------------------------------------------------------------

# Distinct sites so every hazard is armed at once; probabilities high
# enough that every seed's schedule takes multiple hits.
_CHAOS_SPEC = ("wal.write=torn:0.35:0.3,wal.fsync=enospc:0.15,"
               "snapshot.write=error:0.5,snapshot.rename=error:0.5")


@pytest.mark.parametrize("seed", range(8))
def test_seeded_crash_recover_schedule(tmp_path, seed):
    """Random op stream under probabilistic faults; after the crash,
    recovery must reproduce the acked-only live index byte-for-byte."""
    rng = random.Random(seed)
    idx, val = synth.make_corpus(seed, DS, 200, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    acked = set()
    next_id = 0
    faults = 0

    reg = fp.FailpointRegistry(seed=seed,
                               registry=MetricsRegistry()).configure(
                                   _CHAOS_SPEC)
    with _installed(reg):
        for _ in range(40):
            roll = rng.random()
            try:
                if roll < 0.55 or not acked:
                    k = rng.randint(1, 4)
                    ids = list(range(next_id, next_id + k))
                    rows = [i % 200 for i in ids]
                    live.insert_many(ids, idx[rows], val[rows])
                    acked.update(ids)
                    next_id += k
                elif roll < 0.80:
                    e = rng.choice(sorted(acked))
                    live.delete(e)
                    acked.discard(e)
                elif roll < 0.92:
                    live.snapshot()
                else:
                    live.compact()
            except OSError as e:
                assert isinstance(e, fp.InjectedFault)   # only OUR faults
                faults += 1
    assert faults >= 1                      # the schedule actually injected

    # "crash": abandon `live` without closing and recover from disk.
    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    assert set(rec._id2slot) == acked       # zero acked-write loss
    assert rec._id2slot == live._id2slot
    assert rec._free == live._free
    _assert_state_equal(rec.state, live.state)


# ---------------------------------------------------------------------------
# multi-shard batches are all-or-nothing on disk
# ---------------------------------------------------------------------------

MULTI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.core.engine import EngineSpec
    from repro.data import synth
    from repro.distributed import mesh as meshlib
    from repro.fault import failpoints as fp
    from repro.persist import wal
    from repro.persist.durable import DurableShardedSinnamonIndex

    wd = os.path.join(os.environ["CHAOS_TMP"], "wal")
    ds = synth.SparseDatasetSpec("t", n=300, psi_doc=16, psi_query=8,
                                 value_dist="gaussian")
    spec = EngineSpec(n=ds.n, m=12, capacity=96, max_nnz=32, h=2, seed=3)
    idx, val = synth.make_corpus(0, ds, 32, pad=32)
    mesh = meshlib.make_mesh((1, 2), ("data", "model"))
    index = DurableShardedSinnamonIndex.open(spec, mesh, wal_dir=wd)
    index.insert_many(list(range(16)), idx[:16], val[:16])

    def part_bytes():
        return {p: sorted((s, os.path.getsize(os.path.join(wd, p, s)))
                          for s in os.listdir(os.path.join(wd, p)))
                for p in wal.partitions(wd)}

    assert len(wal.partitions(wd)) == 2          # batch really spans shards
    before_bytes = part_bytes()
    before_lsns = [lsn for lsn, _, _ in wal.read_ops(wd)]
    before_next = index._next_lsn
    ids_before = dict(index._id2slot)

    # seed 10 @ prob 0.5: first roll misses, second fires — so the batch's
    # FIRST per-shard append (highest lsn) lands durably, then the second
    # fails, exercising the unappend rollback of the durable record.
    reg = fp.FailpointRegistry(seed=10)
    reg.configure("wal.write=error:0.5")
    fp.set_failpoints(reg)
    try:
        index.insert_many(list(range(16, 32)), idx[16:], val[16:])
        raise SystemExit("expected an injected append failure")
    except OSError:
        pass
    fp.set_failpoints(None)
    assert reg.hits("wal.write") == 1, reg.hits("wal.write")

    # every partition is byte-identical to its pre-batch state: the
    # durable higher-lsn record was rolled back, not stranded.
    assert part_bytes() == before_bytes, (part_bytes(), before_bytes)
    assert [lsn for lsn, _, _ in wal.read_ops(wd)] == before_lsns
    assert index._next_lsn == before_next        # batch lsns not burned
    assert dict(index._id2slot) == ids_before    # nothing applied in memory

    # retry with faults cleared; recovery then equals the live index.
    index.insert_many(list(range(16, 32)), idx[16:], val[16:])
    rec = DurableShardedSinnamonIndex.open(spec, mesh, wal_dir=wd)
    assert rec._id2slot == index._id2slot
    import jax
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), rec.state, index.state)
    print("CHAOS_MULTI_OK")
""")


@pytest.mark.distributed
def test_multi_shard_torn_batch_rolls_back(tmp_path):
    env = dict(os.environ, PYTHONPATH="src", CHAOS_TMP=str(tmp_path))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MULTI], env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "CHAOS_MULTI_OK" in out.stdout


# ---------------------------------------------------------------------------
# tiered store: a failed cold-chunk promotion never poisons the cache
# ---------------------------------------------------------------------------

def test_tiered_promotion_fault_no_cache_poisoning():
    """`vecstore.read` injected during promotion: the query fails cleanly,
    the chunk is NOT marked resident (a poisoned map would serve stale or
    garbage device rows forever after), the freed cache lines are returned,
    and the retry after the fault clears is bit-identical to resident."""
    from repro.core.engine import SinnamonIndex, TieredSinnamonIndex

    idx, val = synth.make_corpus(5, DS, 64, pad=32)
    spec = _spec(capacity=64)
    resident = SinnamonIndex(spec)
    tiered = TieredSinnamonIndex(spec, tier_chunk_slots=8, cache_chunks=8)
    resident.insert_many(list(range(64)), idx, val)
    tiered.insert_many(list(range(64)), idx, val)

    qi, qv = synth.make_queries(5, DS, 2, pad=32)
    resident.search_many(qi, qv, k=5)       # compile outside the fault scope
    tiered.tiered.gather_rows(np.arange(8))  # warm one chunk: mixed-age cache
    before = tiered.tiered.stats()
    assert before["resident_chunks"] == 1

    reg = fp.FailpointRegistry(registry=MetricsRegistry())
    reg.set("vecstore.read", "error", count=1)
    with _installed(reg):
        with pytest.raises(fp.InjectedError):
            tiered.search_many(qi, qv, k=5)
        assert reg.hits("vecstore.read") == 1
    after = tiered.tiered.stats()
    assert after["resident_chunks"] == before["resident_chunks"]
    assert after["promotions"] == before["promotions"]

    # fault cleared: the same query promotes for real and matches resident
    ri, rs = resident.search_many(qi, qv, k=5)
    ti, ts = tiered.search_many(qi, qv, k=5)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ti))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(ts))
    assert tiered.tiered.stats()["promotions"] > before["promotions"]


def test_durable_tiered_promotion_fault_then_crash_recovery(tmp_path):
    """A promotion fault on a durable tiered index touches only cache
    state: the durable (logical) state is unchanged, and recovery after a
    crash immediately following the fault is byte-identical — cache heat
    is not durable state and is rebuilt from zero."""
    from repro.persist.durable import DurableTieredSinnamonIndex

    idx, val = synth.make_corpus(6, DS, 48, pad=32)
    spec = _spec(capacity=64)
    kw = dict(wal_dir=str(tmp_path / "wal"),
              snapshot_dir=str(tmp_path / "snap"),
              tier_chunk_slots=8, cache_chunks=8, fsync=False)
    live = DurableTieredSinnamonIndex.open(spec, **kw)
    live.insert_many(list(range(48)), idx, val)

    qi, qv = synth.make_queries(6, DS, 2, pad=32)
    ids0, sc0 = live.search_many(qi, qv, k=5)       # compile + warm
    st_before = live.logical_state()
    lsn_before = live._next_lsn

    # evict everything the warm query promoted so the faulted retry has
    # cold chunks to promote again
    for c in list(range(live.tiered.num_chunks)):
        if live.tiered._line_by_chunk[c] >= 0:
            live.tiered._evict(c)

    reg = fp.FailpointRegistry(registry=MetricsRegistry())
    reg.set("vecstore.read", "error", count=1)
    with _installed(reg):
        with pytest.raises(fp.InjectedError):
            live.search_many(qi, qv, k=5)
    assert live._next_lsn == lsn_before             # queries never log
    _assert_state_equal(live.logical_state(), st_before)

    del live                                        # crash, no clean close
    rec = DurableTieredSinnamonIndex.open(spec, **kw)
    assert rec.tiered.stats()["resident_chunks"] == 0   # heat not durable
    ids1, sc1 = rec.search_many(qi, qv, k=5)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
    np.testing.assert_array_equal(np.asarray(sc0), np.asarray(sc1))
    _assert_state_equal(rec.logical_state(), st_before)
