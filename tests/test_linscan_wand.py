"""Exactness of the LinScan and WAND baselines (paper §3 / §6.1.4)."""

import numpy as np

from repro.core.linscan import LinScanIndex, brute_force_topk
from repro.core.wand import WandIndex
from repro.data import synth

DS = synth.SparseDatasetSpec("t", n=400, psi_doc=20, psi_query=10,
                             value_dist="gaussian")


def _corpus(n=200):
    idx, val = synth.make_corpus(1, DS, n, pad=40)
    return idx, val


def test_linscan_exact_topk():
    idx, val = _corpus()
    ls = LinScanIndex(DS.n)
    ls.insert_many(range(len(idx)), idx, val)
    qi, qv = synth.make_queries(2, DS, 6, pad=20)
    for b in range(6):
        ids0, sc0 = brute_force_topk(idx, val, qi[b], qv[b], DS.n, 10)
        ids, sc = ls.search(qi[b], qv[b], k=10)
        assert set(ids.tolist()) == set(ids0.tolist())
        np.testing.assert_allclose(np.sort(sc), np.sort(sc0), rtol=1e-5)


def test_linscan_anytime_recall_monotone():
    idx, val = _corpus()
    ls = LinScanIndex(DS.n)
    ls.insert_many(range(len(idx)), idx, val)
    qi, qv = synth.make_queries(3, DS, 8, pad=20)
    small, large = [], []
    for b in range(8):
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], DS.n, 10)
        i1, _ = ls.search(qi[b], qv[b], k=10, kprime=40, posting_budget=40)
        i2, _ = ls.search(qi[b], qv[b], k=10, kprime=40, posting_budget=100000)
        small.append(len(set(i1.tolist()) & set(ids0.tolist())) / 10)
        large.append(len(set(i2.tolist()) & set(ids0.tolist())) / 10)
    assert np.mean(large) >= np.mean(small)
    assert np.mean(large) == 1.0


def test_linscan_full_deletion():
    idx, val = _corpus(50)
    ls = LinScanIndex(DS.n)
    ls.insert_many(range(50), idx, val)
    qi, qv = synth.make_queries(4, DS, 1, pad=20)
    ids, _ = ls.search(qi[0], qv[0], k=5)
    ls.delete(int(ids[0]))
    ls.compact()
    ids2, _ = ls.search(qi[0], qv[0], k=5)
    assert int(ids[0]) not in ids2.tolist()


def test_wand_matches_brute_force():
    idx, val = _corpus(120)
    w = WandIndex(DS.n)
    w.build(range(120), idx, val)
    qi, qv = synth.make_queries(5, DS, 6, pad=20)
    for b in range(6):
        ids0, sc0 = brute_force_topk(idx, val, qi[b], qv[b], DS.n, 10)
        ids, sc = w.search(qi[b], qv[b], k=10)
        # WAND only visits docs intersecting the query; brute force may pad
        # the tail with 0-scored non-matching docs — compare the strictly
        # positive prefix, which is where top-k is well defined.
        j = int((sc0 > 1e-6).sum())
        np.testing.assert_allclose(np.sort(sc)[::-1][:j], sc0[:j],
                                   rtol=1e-4, atol=1e-5)


def test_wand_nonnegative_fast_path():
    ds = synth.BM25_LIKE
    idx, val = synth.make_corpus(6, ds, 100, pad=100)
    w = WandIndex(ds.n)
    w.build(range(100), idx, val)
    qi, qv = synth.make_queries(7, ds, 4, pad=16)
    for b in range(4):
        ids0, sc0 = brute_force_topk(idx, val, qi[b], qv[b], ds.n, 5)
        ids, sc = w.search(qi[b], qv[b], k=5)
        np.testing.assert_allclose(np.sort(sc)[::-1], sc0, rtol=1e-4,
                                   atol=1e-5)
