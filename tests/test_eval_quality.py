"""Quality gates for the accuracy-lever subsystem (repro.eval + variants).

Covers the ISSUE 5 acceptance surface: the lite / quantized sketch variants
hold a recall floor on seeded corpora, the snapshot v2→v3 incompatibility is
an explicit error, the auto-tuner's answer actually meets its constraints,
and the measured per-coordinate overestimate respects the §5 theory bound at
the configured confidence (slack).
"""

import json
import os

import numpy as np
import pytest

from repro.core import theory
from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.eval import bounds as boundslib
from repro.eval import recall as harness
from repro.eval import tune as tunelib

_DOCS, _QUERIES, _K = 1024, 16, 10


def _corpus(kind):
    if kind == "gauss":
        ds = synth.SparseDatasetSpec("eval_gauss", n=2048, psi_doc=32,
                                     psi_query=16, value_dist="gaussian")
    else:
        ds = synth.SparseDatasetSpec("eval_text", n=4096, psi_doc=48,
                                     psi_query=16, value_dist="lognormal",
                                     value_param=0.6, nonneg=True,
                                     activation="zipf")
    idx, val = synth.make_corpus(0, ds, _DOCS, pad=64)
    qi, qv = synth.make_queries(1, ds, _QUERIES, pad=24)
    return ds, idx, val, qi, qv


@pytest.fixture(scope="module")
def gauss():
    return _corpus("gauss")


@pytest.fixture(scope="module")
def text():
    return _corpus("text")


def test_lite_halves_sketch_and_holds_recall_floor(text):
    ds, idx, val, qi, qv = text
    pts = harness.frontier(idx, val, qi, qv, ds.n,
                           [dict(m=48, sketch_kind="full"),
                            dict(m=48, sketch_kind="lite")], k=_K, reps=1)
    full, lite = pts
    assert lite["sketch_bytes"] * 2 == full["sketch_bytes"]
    assert lite["recall_at_k"] >= 0.9
    assert full["recall_at_k"] - lite["recall_at_k"] <= 0.05


def test_quantized_cells_hold_recall_floor(gauss):
    ds, idx, val, qi, qv = gauss
    pts = harness.frontier(idx, val, qi, qv, ds.n,
                           [dict(m=48, cell_dtype="bf16"),
                            dict(m=48, cell_dtype="f8")], k=_K, reps=1)
    bf16, f8 = pts
    assert f8["sketch_bytes"] * 2 == bf16["sketch_bytes"]
    assert f8["recall_at_k"] >= 0.9
    # Directed rounding keeps Theorem 5.1: a quantized upper bound never
    # undershoots the (float32-stored) truth.
    spec = harness.lever_spec(ds.n, _DOCS, idx.shape[1], m=48,
                              cell_dtype="f8")
    index = harness.build_index(spec, idx, val)
    errs = boundslib.per_coordinate_overestimate(index)
    assert errs.min() >= 0.0


def test_lite_on_signed_data_degrades_not_breaks(gauss):
    """On signed values lite loses the lower bound (recall drops) but the
    engine stays functional and the upper-bound property is intact."""
    ds, idx, val, qi, qv = gauss
    pts = harness.frontier(idx, val, qi, qv, ds.n,
                           [dict(m=48, sketch_kind="lite")], k=_K, reps=1)
    assert 0.2 <= pts[0]["recall_at_k"] <= 1.0
    spec = harness.lever_spec(ds.n, _DOCS, idx.shape[1], m=48,
                              sketch_kind="lite")
    index = harness.build_index(spec, idx, val)
    assert boundslib.per_coordinate_overestimate(index).min() >= 0.0


def test_backend_agreement_on_variants(gauss):
    """pallas (fused) and reference backends return identical ids for the
    lite and f8 variants too — switching backends stays a latency decision."""
    ds, idx, val, qi, qv = gauss
    for kind, dt in (("lite", "bf16"), ("full", "f8"), ("lite", "f8")):
        spec = harness.lever_spec(ds.n, 256, idx.shape[1], m=32,
                                  sketch_kind=kind, cell_dtype=dt)
        index = harness.build_index(spec, idx[:256], val[:256])
        for b in range(4):
            ref, _ = index.search(qi[b], qv[b], k=_K, kprime=50,
                                  backend="reference")
            fused, _ = index.search(qi[b], qv[b], k=_K, kprime=50,
                                    backend="pallas")
            assert ref.tolist() == fused.tolist(), (kind, dt, b)


def test_empirical_overestimate_respects_theory(gauss):
    ds, idx, val, qi, qv = gauss
    for dt in ("bf16", "f8"):
        spec = harness.lever_spec(ds.n, _DOCS, idx.shape[1], m=64,
                                  cell_dtype=dt)
        index = harness.build_index(spec, idx, val)
        out = boundslib.check_upper_bounds(
            index, value_dist=theory.gaussian_dist(0.0, 1.0),
            deltas=(0.25, 0.5, 1.0), slack=0.05)
        assert out["ok"], (dt, out["checks"])
        assert out["min_err"] >= 0.0


def test_churn_drift_measured_and_compacted_away(gauss):
    ds, idx, val, _, _ = gauss
    spec = harness.lever_spec(ds.n, 512, idx.shape[1], m=48)
    out = boundslib.churn_overestimate(spec, idx[:512], val[:512],
                                       rounds=1, frac=0.25)
    assert out["churned"]["drift_max"] > 0.0
    assert out["churned"]["err_mean"] >= out["clean"]["err_mean"]
    assert out["compacted"]["drift_max"] == 0.0
    assert out["compacted"]["err_mean"] == pytest.approx(
        out["clean"]["err_mean"], abs=1e-6)
    assert out["columns_rebuilt"] > 0


def test_tuner_meets_constraints(gauss):
    ds, idx, val, qi, qv = gauss
    budget = 1.5e6
    floor = 0.8
    res = tunelib.tune(idx, val, qi, qv, ds.n,
                       memory_budget_bytes=budget, recall_floor=floor,
                       k=_K, ms=(32, 64), cell_dtypes=("bf16", "f8"),
                       sample_docs=768, sample_queries=12)
    assert res.feasible
    assert res.point["recall_at_k"] >= floor
    assert res.point["predicted_index_bytes"] <= budget
    assert tunelib.spec_index_bytes(res.spec) <= budget
    # The returned spec is ready to serve at target scale.
    assert res.spec.capacity >= _DOCS
    index = SinnamonIndex(res.spec)
    index.insert_many(list(range(64)), idx[:64], val[:64])
    ids, _ = index.search(qi[0], qv[0], k=5, kprime=res.kprime)
    assert len(ids) == 5


def test_tuner_reports_infeasible_budget(gauss):
    ds, idx, val, qi, qv = gauss
    res = tunelib.tune(idx, val, qi, qv, ds.n,
                       memory_budget_bytes=1024,   # nothing fits 1 KiB
                       recall_floor=0.5, k=_K, ms=(32,),
                       sample_docs=256, sample_queries=8)
    assert not res.feasible


def test_snapshot_v2_refused_explicitly(tmp_path, gauss):
    from repro.persist import snapshot

    ds, idx, val, _, _ = gauss
    spec = harness.lever_spec(ds.n, 64, idx.shape[1], m=16)
    index = harness.build_index(spec, idx[:64], val[:64])
    snap_dir = str(tmp_path / "snap")
    snapshot.save(snap_dir, index, wal_lsn=0)
    manifest_path = os.path.join(snapshot.step_path(snap_dir, 1),
                                 "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    manifest["extra"]["format"] = "sinnamon-snapshot-v2"
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError) as exc:
        snapshot.load_single(snap_dir)
    msg = str(exc.value)
    assert "sinnamon-snapshot-v2" in msg
    assert "sinnamon-snapshot-v3" in msg
    assert "incompatible" in msg


def test_snapshot_v3_roundtrips_variant_state(tmp_path, gauss):
    """lite + f8 state (no l leaf, uint8-viewed cells) snapshot-restores
    byte-identically through the v3 format."""
    import jax.numpy as jnp

    from repro.persist import snapshot

    ds, idx, val, qi, qv = gauss
    spec = harness.lever_spec(ds.n, 64, idx.shape[1], m=16,
                              sketch_kind="lite", cell_dtype="f8")
    index = harness.build_index(spec, idx[:64], val[:64])
    snap_dir = str(tmp_path / "snap")
    snapshot.save(snap_dir, index, wal_lsn=0)
    restored, lsn = snapshot.load_single(snap_dir)
    assert lsn == 0
    assert restored.spec == index.spec
    assert restored.state.l is None
    assert restored.state.u.dtype == jnp.dtype("float8_e4m3fn")
    assert bool(jnp.all(restored.state.u == index.state.u))
    ids_a, _ = index.search(qi[0], qv[0], k=5)
    ids_b, _ = restored.search(qi[0], qv[0], k=5)
    assert ids_a.tolist() == ids_b.tolist()


def test_spec_rejects_bad_levers():
    with pytest.raises(ValueError, match="sketch_kind"):
        EngineSpec(n=64, m=8, capacity=32, max_nnz=8, sketch_kind="half")
    with pytest.raises(ValueError, match="cell dtype"):
        EngineSpec(n=64, m=8, capacity=32, max_nnz=8, dtype="int8")
    # Lever aliases canonicalize ("f8" must NOT parse as numpy float64).
    spec = EngineSpec(n=64, m=8, capacity=32, max_nnz=8, dtype="f8")
    assert spec.dtype == "float8_e4m3fn"


def test_exact_topk_matches_bruteforce_oracle(gauss):
    from repro.core.linscan import brute_force_topk

    ds, idx, val, qi, qv = gauss
    fast = harness.exact_topk_ids(idx[:256], val[:256], qi[:4], qv[:4],
                                  ds.n, _K)
    for b in range(4):
        ref, _ = brute_force_topk(idx[:256], val[:256], qi[b], qv[b],
                                  ds.n, _K)
        assert set(fast[b].tolist()) == set(ref.tolist())


def test_frontier_rejects_unknown_lever(gauss):
    ds, idx, val, qi, qv = gauss
    with pytest.raises(ValueError, match="unknown lever"):
        harness.frontier(idx[:64], val[:64], qi[:2], qv[:2], ds.n,
                         [dict(m=16, sketchkind="lite")])


def test_quantize_directed_f8_bounds():
    """Directed f8 rounding brackets every finite value (u above, l below)."""
    import jax.numpy as jnp

    from repro.core import sketch

    x = jnp.asarray(np.random.default_rng(0).normal(0, 5, 512),
                    jnp.float32)
    up = sketch.quantize_directed(x, "f8", toward_pos_inf=True)
    dn = sketch.quantize_directed(x, "f8", toward_pos_inf=False)
    assert bool(jnp.all(up.astype(jnp.float32) >= x))
    assert bool(jnp.all(dn.astype(jnp.float32) <= x))
    # saturation: beyond the format's range the bound clamps at max finite
    big = jnp.asarray([1e4, -1e4], jnp.float32)
    assert float(sketch.quantize_directed(big, "f8", True)[0]) == 448.0
    assert float(sketch.quantize_directed(big, "f8", False)[1]) == -448.0
