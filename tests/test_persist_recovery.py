"""Crash recovery: snapshot + WAL tail replay must reproduce the live index
byte-for-byte — including after mid-record WAL truncation, a snapshot taken
in the middle of an insert stream, and (sharded) restore onto a different
shard count."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.distributed import mesh as meshlib
from repro.persist import snapshot as snaplib
from repro.persist import wal
from repro.persist.durable import (DurableShardedSinnamonIndex,
                                   DurableSinnamonIndex)

DS = synth.SparseDatasetSpec("t", n=300, psi_doc=16, psi_query=8,
                             value_dist="gaussian")
N_DOCS = 96


def _spec(capacity=96):
    return EngineSpec(n=DS.n, m=12, capacity=capacity, max_nnz=32, h=2,
                      seed=3, value_dtype="float32")


def _assert_state_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def _assert_search_identical(a, b, nq=4, k=10, kprime=40):
    qi, qv = synth.make_queries(11, DS, nq, pad=16)
    for q in range(nq):
        ids_a, sc_a = a.search(qi[q], qv[q], k=k, kprime=kprime)
        ids_b, sc_b = b.search(qi[q], qv[q], k=k, kprime=kprime)
        np.testing.assert_array_equal(ids_a, ids_b)
        np.testing.assert_array_equal(sc_a, sc_b)


def _stream(index, idx, val):
    """Inserts + snapshot-while-inserting + deletes + recycling re-inserts."""
    index.insert_many(list(range(48)), idx[:48], val[:48])
    index.snapshot()                       # snapshot mid-insert-stream
    index.insert_many(list(range(48, 80)), idx[48:80], val[48:80])
    for e in (3, 17, 48):
        index.delete(e)
    index.insert_many(list(range(80, N_DOCS)), idx[80:], val[80:])
    index.insert(17, idx[1][idx[1] >= 0], val[1][idx[1] >= 0])  # re-insert


def test_single_recovery_is_byte_identical(tmp_path):
    idx, val = synth.make_corpus(0, DS, N_DOCS, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _stream(live, idx, val)

    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    assert rec._id2slot == live._id2slot
    assert rec._free == live._free
    _assert_state_equal(rec.state, live.state)
    _assert_search_identical(rec, live)


def test_recovery_after_compaction_point(tmp_path):
    """Compaction is a logged op: replay rebuilds at the same position."""
    idx, val = synth.make_corpus(1, DS, N_DOCS, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _stream(live, idx, val)
    assert live.compact() > 0
    live.insert(777, idx[2][idx[2] >= 0], val[2][idx[2] >= 0])

    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _assert_state_equal(rec.state, live.state)
    _assert_search_identical(rec, live)


@pytest.mark.parametrize("cut", [1, 7, 13, 64])
def test_truncated_wal_recovers_surviving_prefix(tmp_path, cut):
    """Truncate the WAL at arbitrary byte offsets (mid-payload, mid-header);
    recovery must equal a cleanly built index fed only the surviving ops."""
    idx, val = synth.make_corpus(2, DS, N_DOCS, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _stream(live, idx, val)

    part = os.path.join(wd, wal.partition_name(0))
    seg = os.path.join(part, sorted(os.listdir(part))[-1])
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) - cut)

    snap_lsn = snaplib.latest_wal_lsn(sd)
    survivors = wal.read_ops(wd, after_lsn=snap_lsn)
    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)

    # clean reference: fresh index fed the snapshot base + surviving tail
    clean = DurableSinnamonIndex.open(
        _spec(), wal_dir=str(tmp_path / "wal2"))
    clean.insert_many(list(range(48)), idx[:48], val[:48])   # snapshot base
    with clean._nolog():
        for _, kind, arrays in survivors:
            clean._apply_op(kind, arrays)
    _assert_state_equal(rec.state, clean.state)
    _assert_search_identical(rec, clean)


def test_sharded_recovery_same_mesh(tmp_path):
    idx, val = synth.make_corpus(4, DS, N_DOCS, pad=32)
    mesh = meshlib.single_device_mesh(("data", "model"))
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableShardedSinnamonIndex.open(_spec(), mesh, wal_dir=wd,
                                            snapshot_dir=sd)
    live.insert_many(list(range(48)), idx[:48], val[:48])
    live.snapshot()
    live.delete_many([3, 17])
    live.insert_many(list(range(48, N_DOCS)), idx[48:], val[48:])

    rec = DurableShardedSinnamonIndex.open(_spec(), mesh, wal_dir=wd,
                                           snapshot_dir=sd)
    assert rec._id2slot == live._id2slot
    assert rec._free == live._free
    _assert_state_equal(rec.state, live.state)
    _assert_search_identical(rec, live)


def test_wal_only_recovery_no_snapshot(tmp_path):
    """No snapshot at all: the WAL alone rebuilds the index."""
    idx, val = synth.make_corpus(5, DS, 64, pad=32)
    wd = str(tmp_path / "wal")
    live = DurableSinnamonIndex.open(_spec(64), wal_dir=wd)
    live.insert_many(list(range(64)), idx, val)
    for e in (1, 2):
        live.delete(e)
    rec = DurableSinnamonIndex.open(_spec(64), wal_dir=wd)
    _assert_state_equal(rec.state, live.state)


def test_writer_resumes_after_torn_tail(tmp_path):
    """Recover from a torn WAL, keep writing, recover again."""
    idx, val = synth.make_corpus(6, DS, 64, pad=32)
    wd = str(tmp_path / "wal")
    live = DurableSinnamonIndex.open(_spec(64), wal_dir=wd)
    live.insert_many(list(range(32)), idx[:32], val[:32])
    part = os.path.join(wd, wal.partition_name(0))
    seg = os.path.join(part, sorted(os.listdir(part))[-1])
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) - 5)

    rec = DurableSinnamonIndex.open(_spec(64), wal_dir=wd)
    rec.insert_many(list(range(32, 64)), idx[32:], val[32:])
    rec2 = DurableSinnamonIndex.open(_spec(64), wal_dir=wd)
    _assert_state_equal(rec2.state, rec.state)
    assert rec2.size == rec.size


def test_cross_layout_recovery(tmp_path):
    """A sharded snapshot restores into a single index (and back) via the
    elastic re-insert path; the live doc set and results are preserved."""
    idx, val = synth.make_corpus(8, DS, 64, pad=32)
    mesh = meshlib.single_device_mesh(("data", "model"))
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableShardedSinnamonIndex.open(_spec(64), mesh, wal_dir=wd,
                                            snapshot_dir=sd)
    live.insert_many(list(range(48)), idx[:48], val[:48])
    live.snapshot()
    live.delete_many([1, 2])
    live.insert_many(list(range(48, 64)), idx[48:], val[48:])

    single = DurableSinnamonIndex.open(_spec(64), wal_dir=wd,
                                       snapshot_dir=sd)
    assert single.size == live.size
    assert sorted(single._id2slot) == sorted(live._id2slot)
    qi, qv = synth.make_queries(12, DS, 3, pad=16)
    for q in range(3):
        ids_l, sc_l = live.search(qi[q], qv[q], k=10, kprime=64)
        ids_s, sc_s = single.search(qi[q], qv[q], k=10, kprime=64)
        assert set(ids_l.tolist()) == set(ids_s.tolist())
        np.testing.assert_allclose(np.sort(sc_l), np.sort(sc_s), atol=1e-5)
    # the cross-layout open re-based the snapshot as kind=single; the
    # standalone sharded loader must accept that single-kind snapshot (no
    # update_block/n_shards in its recipe), and a sharded open restores
    # elastically from it
    loaded, _ = snaplib.load_sharded(sd, mesh)
    assert loaded.doc_ids() == single.doc_ids()
    back = DurableShardedSinnamonIndex.open(_spec(64), mesh, wal_dir=wd,
                                            snapshot_dir=sd)
    assert sorted(back._id2slot) == sorted(live._id2slot)


def test_mutation_errors_do_not_poison_the_wal(tmp_path):
    """Failed ops must not be logged: a caught error, then recovery, must
    leave a fully usable, byte-identical index (validate-before-log)."""
    idx, val = synth.make_corpus(9, DS, 32, pad=32)
    wd = str(tmp_path / "wal")
    live = DurableSinnamonIndex.open(_spec(32), wal_dir=wd)
    live.insert_many(list(range(16)), idx[:16], val[:16])
    with pytest.raises(KeyError):
        live.delete(999)                      # unknown id
    with pytest.raises(ValueError):
        live.grow(live.spec.capacity)         # not larger
    with pytest.raises(ValueError):
        live.insert_many([100], idx[:1, :8], val[:1, :8])   # wrong width
    live.insert_many(list(range(16, 32)), idx[16:], val[16:])
    rec = DurableSinnamonIndex.open(_spec(32), wal_dir=wd)
    _assert_state_equal(rec.state, live.state)
    assert rec.size == 32


def test_cross_layout_recovery_with_narrow_batches(tmp_path):
    """Sharded inserts logged from batches narrower than max_nnz must still
    replay into a single index (payloads are padded at log time)."""
    idx, val = synth.make_corpus(10, DS, 32, pad=24)   # 24 < max_nnz=32
    mesh = meshlib.single_device_mesh(("data", "model"))
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableShardedSinnamonIndex.open(_spec(32), mesh, wal_dir=wd,
                                            snapshot_dir=sd)
    live.snapshot()                                    # empty base snapshot
    live.insert_many(list(range(32)), idx, val)        # WAL tail only
    single = DurableSinnamonIndex.open(_spec(32), wal_dir=wd,
                                       snapshot_dir=sd)
    assert single.size == live.size == 32


def test_partial_multi_shard_batch_is_dropped(tmp_path):
    """A batch's per-shard records are appended in descending-LSN order, so
    a crash between appends (high LSN durable, low LSN missing) must make
    replay drop the whole batch via the gap rule, never apply half of it."""
    wd = str(tmp_path / "wal")
    w0, w1 = wal.writer_for(wd, 0), wal.writer_for(wd, 1)
    w0.append(wal.KIND_INSERT, {"ext_ids": np.asarray([1])}, lsn=0)
    # batch spanning shards 0+1 gets lsns 1,2; reverse-order append crashed
    # after writing only lsn 2
    w1.append(wal.KIND_DELETE, {"ext_ids": np.asarray([9])}, lsn=2)
    assert [lsn for lsn, _, _ in wal.read_ops(wd)] == [0]
    wal.repair(wd, 0)                # recovery horizon: drop the orphan
    assert [lsn for lsn, _, _ in wal.read_ops(wd)] == [0]
    assert wal.last_lsn(wd) == 0


def test_partial_batch_at_stream_head_is_dropped(tmp_path):
    """The gap rule must also hold with no snapshot (after_lsn=-1): the very
    first batch spans shards 0+1 (lsns 0,1), the crash left only the
    higher-LSN record durable — replay must yield nothing, not half a batch."""
    wd = str(tmp_path / "wal")
    wal.writer_for(wd, 1).append(wal.KIND_DELETE,
                                 {"ext_ids": np.asarray([9])}, lsn=1)
    assert wal.read_ops(wd) == []
    assert wal.read_ops(wd, after_lsn=-1) == []
    assert wal.last_lsn(wd) == -1


def test_snapshot_is_idempotent_at_same_lsn(tmp_path):
    """snapshot() with no new ops must NOT rewrite the on-disk snapshot —
    rewriting briefly unpublishes the only recovery base (the WAL it covered
    is already pruned).  A second launcher run with the same dirs hits this."""
    idx, val = synth.make_corpus(15, DS, N_DOCS, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    live.insert_many(list(range(48)), idx[:48], val[:48])
    p1 = live.snapshot()
    mtime = os.path.getmtime(os.path.join(p1, "manifest.json"))
    p2 = live.snapshot()
    assert p2 == p1
    assert os.path.getmtime(os.path.join(p1, "manifest.json")) == mtime
    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _assert_state_equal(rec.state, live.state)


def test_cross_layout_replay_of_reinsert(tmp_path):
    """A sharded WAL tail containing an insert_many of an already-live id
    must replay onto a single index with overwrite semantics — one active
    slot per id, stale copy freed, not a duplicated document."""
    idx, val = synth.make_corpus(16, DS, 64, pad=32)
    mesh = meshlib.single_device_mesh(("data", "model"))
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableShardedSinnamonIndex.open(_spec(64), mesh, wal_dir=wd,
                                            snapshot_dir=sd)
    live.insert_many(list(range(32)), idx[:32], val[:32])
    live.snapshot()
    live.insert_many([5, 6], idx[40:42], val[40:42])   # re-insert live ids

    single = DurableSinnamonIndex.open(_spec(64), wal_dir=wd,
                                       snapshot_dir=sd)
    assert single.size == live.size == 32
    assert int(np.asarray(single.state.active).sum()) == 32
    slot = single._id2slot[5]
    np.testing.assert_array_equal(
        np.asarray(single.state.store.indices[slot]), idx[40])


def test_open_refuses_pruned_wal_without_its_snapshot(tmp_path):
    """Opening a pruned WAL without the snapshot it was pruned against must
    raise, NOT 'repair' the unreachable records away (silent data loss)."""
    idx, val = synth.make_corpus(12, DS, N_DOCS, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    live.insert_many(list(range(48)), idx[:48], val[:48])
    live.snapshot()                               # prunes WAL <= snapshot LSN
    live.insert_many(list(range(48, 80)), idx[48:80], val[48:80])

    with pytest.raises(RuntimeError, match="unreachable"):
        DurableSinnamonIndex.open(_spec(), wal_dir=wd)   # forgot snapshot_dir
    survivors = wal.orphan_lsns(wd, -1)
    assert survivors, "refusing open must leave the WAL records intact"
    # with the right snapshot_dir, recovery still works afterwards
    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _assert_state_equal(rec.state, live.state)


def test_duplicate_delete_batch_never_poisons_the_wal(tmp_path):
    """delete_many with a repeated id is one deletion — it must not log a
    record that fails on apply (which would break every future recovery)."""
    idx, val = synth.make_corpus(18, DS, 32, pad=32)
    mesh = meshlib.single_device_mesh(("data", "model"))
    wd = str(tmp_path / "wal")
    live = DurableShardedSinnamonIndex.open(_spec(32), mesh, wal_dir=wd)
    live.insert_many(list(range(16)), idx[:16], val[:16])
    live.delete_many([2, 2, 5])
    assert live.size == 14

    rec = DurableShardedSinnamonIndex.open(_spec(32), mesh, wal_dir=wd)
    assert rec._id2slot == live._id2slot
    _assert_state_equal(rec.state, live.state)


def test_failed_insert_never_poisons_the_wal(tmp_path):
    """An op that will fail must not be logged: after a caller-handled batch
    length mismatch, recovery must still succeed (validate-before-log)."""
    idx, val = synth.make_corpus(13, DS, N_DOCS, pad=32)
    wd = str(tmp_path / "wal")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd)
    live.insert_many(list(range(8)), idx[:8], val[:8])
    with pytest.raises(ValueError, match="length mismatch"):
        live.insert_many([100, 101, 102], idx[8:10], val[8:10])
    live.insert_many([100, 101], idx[8:10], val[8:10])

    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd)
    assert rec._id2slot == live._id2slot
    _assert_state_equal(rec.state, live.state)


def test_corrupt_record_header_is_rejected(tmp_path):
    """The CRC covers the header too: a flipped kind/lsn byte must make the
    record undecodable (treated as a torn tail), not crash or misreplay."""
    idx, val = synth.make_corpus(14, DS, N_DOCS, pad=32)
    wd = str(tmp_path / "wal")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd)
    live.insert_many(list(range(8)), idx[:8], val[:8])
    live.insert_many(list(range(8, 16)), idx[8:16], val[8:16])

    part = os.path.join(wd, wal.partition_name(0))
    seg = os.path.join(part, sorted(os.listdir(part))[-1])
    assert len(wal.read_ops(wd)) == 2
    with open(seg, "r+b") as f:        # flip the LAST record's kind byte
        first_plen = wal._HEADER.unpack(f.read(wal._HEADER.size))[3]
        second_off = wal._HEADER.size + first_plen
        f.seek(second_off + 12)        # kind field: after magic(4)+lsn(8)
        f.write(bytes([wal.KIND_DELETE]))
    assert [lsn for lsn, _, _ in wal.read_ops(wd)] == [0]

    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd)
    assert sorted(rec._id2slot) == list(range(8))


def test_corrupt_mid_stream_segment_refuses_repair(tmp_path):
    """A bit-rotted record hides only the rest of ITS segment: records in
    later segments stay visible as orphans, so open() must refuse to repair
    (raise) instead of silently deleting the acknowledged later segments."""
    idx, val = synth.make_corpus(17, DS, N_DOCS, pad=32)
    wd = str(tmp_path / "wal")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, segment_bytes=1)
    for d in range(8):                       # 1-byte segments: one per record
        keep = idx[d] >= 0
        live.insert(d, idx[d][keep], val[d][keep])

    part = os.path.join(wd, wal.partition_name(0))
    segs = sorted(os.listdir(part))
    assert len(segs) == 8
    p = os.path.join(part, segs[2])
    with open(p, "r+b") as f:                # flip one payload byte
        f.seek(0, os.SEEK_END)
        size = f.tell()
        f.seek(size - 1)
        byte = f.read(1)
        f.seek(size - 1)
        f.write(bytes([byte[0] ^ 0xFF]))

    with pytest.raises(RuntimeError, match="unreachable"):
        DurableSinnamonIndex.open(_spec(), wal_dir=wd, segment_bytes=1)
    assert sorted(os.listdir(part)) == segs  # refusal deleted nothing


def test_query_server_serves_during_maintenance(tmp_path):
    """Snapshots + background compaction must not disturb serving: queries
    issued while maintenance runs return the same answers as afterwards."""
    from repro.persist import compact
    from repro.serving.serve import QueryServer

    idx, val = synth.make_corpus(7, DS, N_DOCS, pad=32)
    wd, sd = str(tmp_path / "wal"), str(tmp_path / "snap")
    live = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    live.insert_many(list(range(64)), idx[:64], val[:64])
    for e in (1, 5, 9):
        live.delete(e)
    live.insert_many([200, 201, 202], idx[64:67], val[64:67])

    srv = QueryServer(live, k=10, kprime=64)
    qi, qv = synth.make_queries(13, DS, 4, pad=16)
    bc = compact.BackgroundCompactor(live, threshold=0.0,
                                     interval_s=0.01).start()
    try:
        answers = [srv.query(qi[q], qv[q]) for q in range(4)]
        live.snapshot()
        answers2 = [srv.query(qi[q], qv[q]) for q in range(4)]
    finally:
        bc.stop()
    # compaction only TIGHTENS bounds; with kprime=capacity the result set
    # is exact either way, so answers must be stable across maintenance
    for (a, sa), (b, sb) in zip(answers, answers2):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(sa, sb)
    assert srv.stats["queries"] == 8
    # and the maintained state recovers byte-identically
    rec = DurableSinnamonIndex.open(_spec(), wal_dir=wd, snapshot_dir=sd)
    _assert_state_equal(rec.state, live.state)


MULTI = textwrap.dedent("""
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    sys.path.insert(0, "src")
    import numpy as np
    from repro.core.engine import EngineSpec
    from repro.data import synth
    from repro.distributed import mesh as meshlib
    from repro.persist.durable import DurableShardedSinnamonIndex

    ds = synth.SparseDatasetSpec("t", n=300, psi_doc=16, psi_query=8,
                                 value_dist="gaussian")
    idx, val = synth.make_corpus(0, ds, 96, pad=32)
    spec = EngineSpec(n=300, m=12, capacity=64, max_nnz=32, h=2,
                      value_dtype="float32")
    mesh2 = meshlib.make_mesh((1, 2), ("data", "model"))
    mesh1 = meshlib.make_mesh((1, 1), ("data", "model"))
    d = tempfile.mkdtemp()
    wd, sd = os.path.join(d, "wal"), os.path.join(d, "snap")
    live = DurableShardedSinnamonIndex.open(spec, mesh2, wal_dir=wd,
                                            snapshot_dir=sd)
    live.insert_many(list(range(64)), idx[:64], val[:64])
    live.snapshot()
    live.delete_many([3, 10, 20])
    live.insert_many(list(range(64, 96)), idx[64:], val[64:])
    qi, qv = synth.make_queries(1, ds, 4, pad=16)

    ok = True
    # same-mesh recovery: byte-identical results
    rec = DurableShardedSinnamonIndex.open(spec, mesh2, wal_dir=wd,
                                           snapshot_dir=sd)
    for b in range(4):
        a, sa = live.search(qi[b], qv[b], k=10, kprime=64)
        r, sr = rec.search(qi[b], qv[b], k=10, kprime=64)
        ok &= bool(np.array_equal(a, r)) and bool(np.array_equal(sa, sr))
    # elastic: 2-shard snapshot+wal restored onto a 1-shard mesh
    rec1 = DurableShardedSinnamonIndex.open(
        EngineSpec(n=300, m=12, capacity=128, max_nnz=32, h=2,
                   value_dtype="float32"),
        mesh1, wal_dir=wd, snapshot_dir=sd)
    ok &= rec1.size == live.size and rec1.n_shards == 1
    for b in range(4):
        a, sa = live.search(qi[b], qv[b], k=10, kprime=128)
        r, sr = rec1.search(qi[b], qv[b], k=10, kprime=128)
        ok &= set(a.tolist()) == set(r.tolist())
        ok &= bool(np.allclose(np.sort(sa), np.sort(sr), atol=1e-5))
    print("PERSIST_OK" if ok else "PERSIST_BAD")
""")


@pytest.mark.distributed
def test_elastic_shard_count_subprocess():
    out = subprocess.run([sys.executable, "-c", MULTI], capture_output=True,
                         text=True, cwd=".", timeout=600)
    assert "PERSIST_OK" in out.stdout, out.stdout + out.stderr[-3000:]
