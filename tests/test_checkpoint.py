"""Checkpoint/restart + elastic resharding + preemption resume (deliverable:
fault tolerance)."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import loaders, synth
from repro.models import transformer as tr
from repro.optim import adamw
from repro.train import loop


def _tiny_cfg():
    return tr.LMConfig("t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                       d_ff=64, vocab=128, head_dim=16, attn_chunk=8,
                       attn_q_chunk=8)


def test_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    state = loop.init_state(params)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state, extra={"note": "hello"})
    restored, step, extra = ckpt.restore(d, state)
    assert step == 7 and extra["note"] == "hello"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_gc_keeps_latest(tmp_path):
    cfg = _tiny_cfg()
    state = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, state, keep=2)
    assert ckpt.all_steps(d) == [4, 5]


def test_resave_same_step_and_stray_dirs(tmp_path):
    """Re-saving an existing step must replace it without losing the copy;
    in-flight .tmp dirs and superseded .old leftovers never count as
    steps (the .old is cleaned up once its final dir exists)."""
    cfg = _tiny_cfg()
    state = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state, extra={"v": 1})
    ckpt.save(d, 3, state, extra={"v": 2})
    _, step, extra = ckpt.restore(d, state)
    assert step == 3 and extra["v"] == 2
    os.makedirs(os.path.join(d, "step_0000000003.old"))   # leftover
    os.makedirs(os.path.join(d, "step_0000000008.tmp"))
    assert ckpt.all_steps(d) == [3]
    ckpt.adopt_strays(d)                   # writer-side crash repair
    assert not os.path.exists(os.path.join(d, "step_0000000003.old"))
    assert ckpt.all_steps(d) == [3]


def test_adopts_stranded_old_after_crashed_resave(tmp_path):
    """A crash between save()'s two swap renames leaves the previously
    published copy at step_<N>.old with step_<N> gone; writer-side repair
    (adopt_strays — run by save() and by durable recovery) must promote it
    back so the step stays recoverable."""
    cfg = _tiny_cfg()
    state = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state, extra={"v": 1})
    os.rename(os.path.join(d, "step_0000000003"),
              os.path.join(d, "step_0000000003.old"))
    assert ckpt.all_steps(d) == []             # listings stay pure reads
    ckpt.adopt_strays(d)
    assert ckpt.all_steps(d) == [3]            # adopted back
    _, step, extra = ckpt.restore(d, state)
    assert step == 3 and extra["v"] == 1


def test_preemption_resume_loss_continuity(tmp_path):
    """Train 6 steps; kill at 3 + restart == uninterrupted run (bitwise)."""
    cfg = _tiny_cfg()
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=10)

    def loss_fn(params, batch):
        return tr.lm_loss(params, batch[0], batch[1], cfg)

    step_fn = jax.jit(loop.make_train_step(loss_fn, opt_cfg))

    def batch_at(i):
        t, l = loaders.lm_batch(0, i, 4, 16, cfg.vocab)
        return (jnp.asarray(t), jnp.asarray(l))

    # run A: 6 uninterrupted steps
    sa = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    for i in range(6):
        sa, ma = step_fn(sa, batch_at(i))

    # run B: 3 steps, checkpoint, "preemption", restore, 3 more
    d = str(tmp_path / "ck")
    sb = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    for i in range(3):
        sb, _ = step_fn(sb, batch_at(i))
    ckpt.save(d, 3, sb)
    del sb
    template = loop.init_state(tr.init_params(jax.random.PRNGKey(0), cfg))
    sb, step, _ = ckpt.restore(d, template)
    assert step == 3
    for i in range(step, 6):
        sb, mb = step_fn(sb, batch_at(i))

    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), sa.params, sb.params)


def test_elastic_restore_reshard(tmp_path):
    """Checkpoints restore onto a different mesh (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import mesh as meshlib
    cfg = _tiny_cfg()
    params = tr.init_params(jax.random.PRNGKey(1), cfg)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, params)
    mesh = meshlib.single_device_mesh(("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), params)
    restored, _, _ = ckpt.restore(d, params, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf, jax.Array)


def test_index_checkpoint_roundtrip(tmp_path):
    """The retrieval index itself checkpoints/restores (streaming state)."""
    ds = synth.SparseDatasetSpec("t", n=200, psi_doc=12, psi_query=8)
    idx, val = synth.make_corpus(0, ds, 64, pad=24)
    spec = EngineSpec(n=200, m=8, capacity=64, max_nnz=24, h=1)
    index = SinnamonIndex(spec)
    index.insert_many(list(range(64)), idx, val)
    d = str(tmp_path / "ick")
    ckpt.save(d, 1, index.state,
              extra={"spec": dataclasses.asdict(spec),
                     "id2slot": {str(k): v for k, v in
                                 index._id2slot.items()}})
    st2, _, extra = ckpt.restore(d, index.state)
    index2 = SinnamonIndex(spec)
    index2.state = jax.tree.map(jnp.asarray, st2)
    index2._id2slot = {int(k): int(v) for k, v in extra["id2slot"].items()}
    index2._free = [s for s in range(spec.capacity)
                    if s not in index2._id2slot.values()]
    qi, qv = synth.make_queries(1, ds, 1, pad=16)
    a, _ = index.search(qi[0], qv[0], k=5, kprime=32)
    b, _ = index2.search(qi[0], qv[0], k=5, kprime=32)
    assert np.array_equal(a, b)
