"""ISSUE 7 tentpole contracts: the async serving front door.

* Coalesced-batch answers are BIT-IDENTICAL (ids and scores) to per-query
  ``QueryServer.query`` — with actual coalescing asserted, not assumed.
* Backpressure: a full admission queue rejects synchronously with a
  retry-after hint; nothing blocks silently.
* Deadline expiry: queries whose budget elapses while queued behind a
  stalled device are dropped and counted, not served late.
* Per-tenant token-bucket quotas throttle one tenant without touching
  another.
* The HTTP front door speaks 200 / 429+Retry-After / 400 and serves the
  standard /metrics family on the same port.
* ``QueryResult`` is frozen, typed, and still unpacks as ``(ids, scores)``.

Device-independent behaviours (backpressure, expiry, quotas) run against a
stub server so the tests control time and stalls exactly; bit-identity and
the HTTP round trip run against the real engine.
"""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.engine import EngineSpec, SinnamonIndex
from repro.data import synth
from repro.obs import FlightRecorder, MetricsRegistry
from repro.obs.metrics import parse_exposition
from repro.serving.frontend import (DeadlineExceeded, FrontendServer,
                                    Rejected, ServingFrontend, TenantQuota)
from repro.serving.results import QueryResult
from repro.serving.serve import QueryServer

DS = synth.SparseDatasetSpec("fe", n=400, psi_doc=20, psi_query=10,
                             value_dist="gaussian")
N_DOCS = 96


@pytest.fixture(scope="module")
def served():
    idx, val = synth.make_corpus(0, DS, N_DOCS, pad=32)
    qi, qv = synth.make_queries(1, DS, 16, pad=16)
    index = SinnamonIndex(EngineSpec(n=DS.n, m=12, capacity=128, max_nnz=32,
                                     h=2, seed=3, value_dtype="float32"))
    index.insert_many(list(range(N_DOCS)), idx[:N_DOCS], val[:N_DOCS])
    server = QueryServer(index, k=10, kprime=40)
    return server, qi, qv


class _StubServer:
    """Device stand-in: controllable stall, records dispatched batches."""

    def __init__(self, k=4, delay_s=0.0, gate: threading.Event = None):
        self.k = k
        self.delay_s = delay_s
        self.gate = gate
        self.batches = []

    def query_many(self, qi, qv, ctx=None):
        if self.gate is not None:
            self.gate.wait()
        if self.delay_s:
            import time
            time.sleep(self.delay_s)
        self.batches.append(qi.shape[0])
        B = qi.shape[0]
        ids = np.tile(np.arange(self.k, dtype=np.int64), (B, 1))
        scores = np.zeros((B, self.k), np.float32)
        return QueryResult(ids=ids, scores=scores, k=self.k,
                           backend="stub", trace_id="q-stub")


def _q(seed=0, nnz=8):
    rng = np.random.default_rng(seed)
    return (rng.choice(DS.n, nnz, replace=False).astype(np.int32),
            rng.random(nnz, np.float32))


# ---------------------------------------------------------------------------
# bit-identity of coalesced batches (real engine)
# ---------------------------------------------------------------------------

def test_coalesced_bit_identical_to_per_query(served):
    server, qi, qv = served
    expect = [server.query(qi[b], qv[b]) for b in range(qi.shape[0])]
    fe = ServingFrontend(server, max_batch=8, batch_window_ms=50.0,
                         queue_depth=64)
    try:
        fe.query(qi[0], qv[0])                       # compile warmup
        futs = [fe.submit(qi[b], qv[b]) for b in range(qi.shape[0])]
        got = [f.result(timeout=60) for f in futs]
    finally:
        fe.close()
    for b, (g, e) in enumerate(zip(got, expect)):
        np.testing.assert_array_equal(np.asarray(g.ids), np.asarray(e.ids),
                                      err_msg=f"query {b}: ids differ")
        np.testing.assert_array_equal(
            np.asarray(g.scores), np.asarray(e.scores),
            err_msg=f"query {b}: scores not bit-identical")
        assert g.k == e.k and g.backend == e.backend


def test_batches_actually_coalesce():
    """The identity test must not pass vacuously via batch-of-1 dispatches."""
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    fe = ServingFrontend(stub, max_batch=8, batch_window_ms=5.0,
                         queue_depth=64)
    try:
        qi, qv = _q()
        futs = [fe.submit(qi, qv) for _ in range(8)]
        gate.set()                    # stall admission, then release
        for f in futs:
            f.result(timeout=30)
    finally:
        fe.close()
    assert max(stub.batches) > 1, (
        f"8 concurrent submits never coalesced: dispatched {stub.batches}")


def test_mixed_widths_pad_without_crosstalk(served):
    """Different-nnz queries coalesced into one rectangle answer as alone."""
    server, qi, qv = served
    short_i, short_v = qi[0][:6].copy(), qv[0][:6].copy()
    expect_short = server.query(short_i, short_v)
    expect_full = server.query(qi[1], qv[1])
    fe = ServingFrontend(server, max_batch=4, batch_window_ms=50.0,
                         queue_depth=16)
    try:
        fe.query(qi[0], qv[0])                       # compile warmup
        fa = fe.submit(short_i, short_v)
        fb = fe.submit(qi[1], qv[1])
        ga, gb = fa.result(timeout=60), fb.result(timeout=60)
    finally:
        fe.close()
    np.testing.assert_array_equal(np.asarray(ga.ids),
                                  np.asarray(expect_short.ids))
    np.testing.assert_array_equal(np.asarray(ga.scores),
                                  np.asarray(expect_short.scores))
    np.testing.assert_array_equal(np.asarray(gb.ids),
                                  np.asarray(expect_full.ids))
    np.testing.assert_array_equal(np.asarray(gb.scores),
                                  np.asarray(expect_full.scores))


# ---------------------------------------------------------------------------
# backpressure / deadline / quotas (stub device)
# ---------------------------------------------------------------------------

def test_backpressure_rejects_at_full_queue():
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    reg = MetricsRegistry()
    fe = ServingFrontend(stub, max_batch=2, batch_window_ms=1000.0,
                         queue_depth=4, registry=reg)
    try:
        qi, qv = _q()
        held = [fe.submit(qi, qv) for _ in range(4)]   # device is stalled
        with pytest.raises(Rejected) as exc:
            fe.submit(qi, qv)
        assert exc.value.reason == "queue_full"
        assert exc.value.retry_after_ms > 0
        gate.set()
        for f in held:                # queued work still completes after
            f.result(timeout=30)
        snap = json.loads(reg.to_json())
        rej = [s["value"]
               for s in snap["repro_frontend_rejected_total"]["series"]
               if s["labels"].get("reason") == "queue_full"]
        assert rej == [1]
    finally:
        fe.close()


def test_deadline_expiry_under_stalled_device():
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    reg = MetricsRegistry()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=16, default_deadline_ms=30.0,
                         registry=reg)
    try:
        qi, qv = _q()
        blocker = fe.submit(qi, qv, deadline_ms=60_000)  # occupies device
        import time
        time.sleep(0.02)              # let the dispatcher pick blocker up
        doomed = [fe.submit(qi, qv, deadline_ms=20.0) for _ in range(3)]
        time.sleep(0.1)               # deadlines elapse while device stalls
        gate.set()
        blocker.result(timeout=30)
        for f in doomed:
            with pytest.raises(DeadlineExceeded) as exc:
                f.result(timeout=30)
            assert exc.value.queued_ms >= 20.0
        snap = json.loads(reg.to_json())
        exp = snap["repro_frontend_expired_total"]["series"]
        assert [s["value"] for s in exp] == [3]
    finally:
        fe.close()


def test_per_tenant_quota_isolation():
    stub = _StubServer()
    reg = MetricsRegistry()
    fe = ServingFrontend(
        stub, max_batch=4, batch_window_ms=0.0, queue_depth=64,
        quotas={"limited": TenantQuota(rate_qps=1.0, burst=2)},
        registry=reg)
    try:
        qi, qv = _q()
        # limited tenant: burst of 2 admitted, third throttled
        ok = [fe.submit(qi, qv, tenant="limited") for _ in range(2)]
        with pytest.raises(Rejected) as exc:
            fe.submit(qi, qv, tenant="limited")
        assert exc.value.reason == "throttled"
        assert exc.value.tenant == "limited"
        assert exc.value.retry_after_ms > 0
        # unthrottled tenant is untouched by the other tenant's bucket
        free = [fe.submit(qi, qv, tenant="free") for _ in range(16)]
        for f in ok + free:
            f.result(timeout=30)
        snap = json.loads(reg.to_json())
        throttled = {s["labels"]["tenant"]: s["value"]
                     for s in
                     snap["repro_frontend_throttled_total"]["series"]}
        assert throttled == {"limited": 1}
    finally:
        fe.close()


def test_quota_refills_over_time():
    stub = _StubServer()
    t = [0.0]
    fe = ServingFrontend(
        stub, max_batch=4, batch_window_ms=0.0, queue_depth=64,
        default_quota=TenantQuota(rate_qps=10.0, burst=1),
        clock=lambda: t[0])
    try:
        qi, qv = _q()
        f1 = fe.submit(qi, qv)
        with pytest.raises(Rejected):
            fe.submit(qi, qv)
        t[0] += 0.2                   # 0.2s at 10 qps -> 2 tokens back
        f2 = fe.submit(qi, qv)
        for f in (f1, f2):
            f.result(timeout=30)
    finally:
        fe.close()


def test_close_without_drain_fails_queued_futures():
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    fe = ServingFrontend(stub, max_batch=1, batch_window_ms=0.0,
                         queue_depth=16)
    qi, qv = _q()
    stuck = fe.submit(qi, qv)
    import time
    time.sleep(0.02)
    queued = [fe.submit(qi, qv) for _ in range(3)]
    threading.Timer(0.05, gate.set).start()
    fe.close(drain=False)
    stuck.result(timeout=30)          # in-flight dispatch still completes
    for f in queued:
        with pytest.raises(Rejected) as exc:
            f.result(timeout=30)
        assert exc.value.reason == "shutdown"
    with pytest.raises(RuntimeError):
        fe.submit(qi, qv)


# ---------------------------------------------------------------------------
# HTTP front door
# ---------------------------------------------------------------------------

def test_http_round_trip(served):
    server, qi, qv = served
    expect = server.query(qi[2], qv[2])
    reg = MetricsRegistry()
    fe = ServingFrontend(server, max_batch=4, batch_window_ms=1.0,
                         queue_depth=32, registry=reg)
    try:
        with FrontendServer(fe, port=0, registry=reg) as door:
            body = json.dumps({"indices": qi[2].tolist(),
                               "values": qv[2].tolist()}).encode()
            req = urllib.request.Request(door.url + "/v1/query", data=body,
                                         method="POST")
            doc = json.loads(urllib.request.urlopen(req, timeout=60).read())
            assert doc["ids"] == [int(i) for i in np.asarray(expect.ids)]
            np.testing.assert_array_equal(
                np.asarray(doc["scores"], np.float32),
                np.asarray(expect.scores, np.float32))
            assert doc["k"] == expect.k
            assert doc["backend"] == expect.backend
            assert doc["trace_id"].startswith("q-")
            # malformed -> 400
            with pytest.raises(urllib.error.HTTPError) as exc:
                urllib.request.urlopen(urllib.request.Request(
                    door.url + "/v1/query", data=b'{"indices": [1, 2]}',
                    method="POST"), timeout=30)
            assert exc.value.code == 400
            # metrics family on the same port
            scrape = urllib.request.urlopen(door.url + "/metrics",
                                            timeout=30).read().decode()
            names = {n for (n, _l) in parse_exposition(scrape)}
            assert any(n.startswith("repro_frontend_requests_total")
                       for n in names)
            assert urllib.request.urlopen(
                door.url + "/healthz", timeout=30).read() == b"ok\n"
    finally:
        fe.close()


def test_http_429_with_retry_after():
    stub = _StubServer(gate=threading.Event())       # never released
    fe = ServingFrontend(stub, max_batch=1, batch_window_ms=0.0,
                         queue_depth=1)
    try:
        with FrontendServer(fe, port=0) as door:
            qi, qv = _q()
            fe.submit(qi, qv)          # dispatcher picks this up and stalls
            import time
            time.sleep(0.05)
            fe.submit(qi, qv)          # fills the depth-1 queue
            body = json.dumps({"indices": qi.tolist(),
                               "values": qv.tolist()}).encode()
            with pytest.raises(urllib.error.HTTPError) as exc:
                urllib.request.urlopen(urllib.request.Request(
                    door.url + "/v1/query", data=body, method="POST"),
                    timeout=30)
            assert exc.value.code == 429
            assert int(exc.value.headers["Retry-After"]) >= 1
            detail = json.loads(exc.value.read())
            assert detail["reason"] == "queue_full"
    finally:
        fe.close(drain=False)


# ---------------------------------------------------------------------------
# end-to-end request tracing + flight recorder (ISSUE 8)
# ---------------------------------------------------------------------------

def test_stage_attribution_sums_to_latency(served):
    """An OK trace carries quota/queue/assembly/device/respond stages whose
    durations account for the end-to-end latency, plus batch annotations
    that join against the batch record."""
    server, qi, qv = served
    rec = FlightRecorder(capacity=64, sample_rate=1.0, spill=False,
                         registry=MetricsRegistry())
    fe = ServingFrontend(server, max_batch=4, batch_window_ms=1.0,
                         queue_depth=32, recorder=rec)
    try:
        fe.query(qi[0], qv[0])                       # compile warmup
        res = fe.query(qi[1], qv[1])
    finally:
        fe.close()
    trace = rec.get(res.trace_id)
    assert trace is not None and trace["outcome"] == "ok"
    names = [s["stage"] for s in trace["stages"]]
    assert {"quota", "queue", "assembly", "device", "respond"} <= set(names)
    stage_sum = sum(s["ms"] for s in trace["stages"]
                    if not s["stage"].startswith("device/"))
    total = trace["total_ms"]
    assert 0.5 * total <= stage_sum <= 1.5 * total + 1.0, (
        f"stage sum {stage_sum:.3f}ms does not account for total "
        f"{total:.3f}ms: {trace['stages']}")
    # batch annotations join request <-> batch records in both directions
    assert trace["batch_size"] >= 1
    assert trace["width_bucket"] % fe.query_pad == 0
    assert 0.0 <= trace["padding_fraction"] < 1.0
    batch = rec.get_batch(trace["batch_id"])
    assert batch is not None and res.trace_id in batch["trace_ids"]
    assert any(s["stage"] == "device" for s in batch["stages"])


def test_rejected_and_expired_recoverable_from_recorder():
    """The requests an operator must explain — rejections and deadline
    misses — are always retained, with the exception's trace_id resolving
    to stages for exactly the pipeline they traversed."""
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    rec = FlightRecorder(capacity=64, sample_rate=0.0, spill=False,
                         registry=MetricsRegistry())
    fe = ServingFrontend(stub, max_batch=1, batch_window_ms=0.0,
                         queue_depth=2, default_deadline_ms=60_000,
                         recorder=rec)
    try:
        qi, qv = _q()
        blocker = fe.submit(qi, qv)    # dispatcher picks this up and stalls
        import time
        time.sleep(0.02)
        doomed = fe.submit(qi, qv, deadline_ms=10.0)
        fe.submit(qi, qv)              # fills the depth-2 queue
        with pytest.raises(Rejected) as rej:
            fe.submit(qi, qv)
        time.sleep(0.05)               # doomed's deadline elapses in-queue
        gate.set()
        blocker.result(timeout=30)
        with pytest.raises(DeadlineExceeded) as exp:
            doomed.result(timeout=30)
    finally:
        fe.close()
    r = rec.get(rej.value.trace_id)
    assert r is not None and r["outcome"] == "rejected_queue_full"
    assert r["retained"] == "outcome"
    assert r["retry_after_ms"] > 0 and r["queue_depth"] == 2
    assert [s["stage"] for s in r["stages"]] == ["quota"]  # never queued
    e = rec.get(exp.value.trace_id)
    assert e is not None and e["outcome"] == "expired"
    assert "deadline" in e["error"]
    queue_ms = sum(s["ms"] for s in e["stages"] if s["stage"] == "queue")
    assert queue_ms >= 10.0            # the wait that killed it is on record
    assert [r2["outcome"] for r2 in rec.recent(outcome="rejected")] \
        == ["rejected_queue_full"]


def test_loadgen_outcome_accounting_matches_counters():
    """Client-observed outcomes and the frontend counters agree exactly:
    submitted == ok + rejected + expired (no silent drops, no double
    counting)."""
    gate = threading.Event()
    stub = _StubServer(gate=gate)
    reg = MetricsRegistry()
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=8,
                         quotas={"lim": TenantQuota(rate_qps=0.001, burst=2)},
                         registry=reg)
    qi, qv = _q()
    client = {"ok": 0, "rejected": 0, "expired": 0}
    futs, submitted = [], 0
    import time

    def try_submit(**kw):
        nonlocal submitted
        submitted += 1
        try:
            futs.append(fe.submit(qi, qv, **kw))
        except Rejected:
            client["rejected"] += 1

    try:
        try_submit()                   # blocker: dispatched, then stalls
        time.sleep(0.02)
        for _ in range(3):
            try_submit(deadline_ms=20.0)        # will expire in-queue
        for _ in range(3):
            try_submit(tenant="lim")            # 2 admitted, 1 throttled
        for _ in range(3):
            try_submit()                        # fills the queue to 8
        try_submit()                            # 9th -> queue_full
        time.sleep(0.1)                # deadlines elapse while stalled
        gate.set()
        for f in futs:
            try:
                f.result(timeout=30)
                client["ok"] += 1
            except DeadlineExceeded:
                client["expired"] += 1
    finally:
        fe.close()
    assert submitted == 11
    assert client == {"ok": 6, "rejected": 2, "expired": 3}
    snap = json.loads(reg.to_json())
    by_outcome = {}
    for s in snap["repro_frontend_requests_total"]["series"]:
        out = s["labels"]["outcome"]
        by_outcome[out] = by_outcome.get(out, 0) + s["value"]
    assert sum(by_outcome.values()) == submitted
    assert by_outcome["ok"] == client["ok"]
    assert by_outcome["expired"] == client["expired"]
    assert by_outcome["rejected_throttled"] \
        + by_outcome["rejected_queue_full"] == client["rejected"]


def test_front_door_serves_readyz_and_debug_surfaces():
    """The serving port itself answers /readyz (dispatcher + queue checks)
    and the /debug/* flight-recorder surfaces."""
    stub = _StubServer()
    rec = FlightRecorder(capacity=64, sample_rate=1.0, spill=False,
                         registry=MetricsRegistry())
    fe = ServingFrontend(stub, max_batch=4, batch_window_ms=0.0,
                         queue_depth=16, recorder=rec)
    closed = False
    try:
        with FrontendServer(fe, port=0, recorder=rec) as door:
            qi, qv = _q()
            res = fe.query(qi, qv)
            ready = json.loads(urllib.request.urlopen(
                door.url + "/readyz", timeout=30).read())
            assert ready["ready"] is True
            assert set(ready["checks"]) == {"dispatcher", "admission_queue"}
            doc = json.loads(urllib.request.urlopen(
                door.url + "/debug/requests?outcome=ok", timeout=30).read())
            assert doc["count"] >= 1
            assert any(r["trace_id"] == res.trace_id
                       for r in doc["requests"])
            trace = json.loads(urllib.request.urlopen(
                door.url + f"/debug/trace/{res.trace_id}",
                timeout=30).read())
            assert trace["outcome"] == "ok"
            batches = json.loads(urllib.request.urlopen(
                door.url + "/debug/batches", timeout=30).read())
            assert batches["count"] >= 1
            # a dead dispatcher flips /readyz to 503 with the reason
            fe.close()
            closed = True
            with pytest.raises(urllib.error.HTTPError) as exc:
                urllib.request.urlopen(door.url + "/readyz", timeout=30)
            assert exc.value.code == 503
            detail = json.loads(exc.value.read())
            assert detail["checks"]["dispatcher"]["ok"] is False
    finally:
        if not closed:
            fe.close()


# ---------------------------------------------------------------------------
# QueryResult typing
# ---------------------------------------------------------------------------

def test_query_result_typed_and_frozen(served):
    server, qi, qv = served
    res = server.query(qi[0], qv[0])
    assert isinstance(res, QueryResult)
    assert res.k == 10
    assert res.backend in ("reference", "grouped", "pallas", "custom")
    assert res.trace_id.startswith("q-")
    with pytest.raises(AttributeError):
        res.k = 99
    # legacy tuple-compat: unpack, index, len
    ids, scores = res
    assert ids is res.ids and scores is res.scores
    assert res[0] is res.ids and res[1] is res.scores
    assert len(res) == 2
    assert res.batch_size is None
    batched = server.query_many(qi[:4], qv[:4])
    assert batched.batch_size == 4
    row = batched.row(2, k=5, trace_id="q-test")
    assert row.ids.shape == (5,) and row.k == 5
    np.testing.assert_array_equal(np.asarray(row.ids),
                                  np.asarray(batched.ids)[2, :5])
    with pytest.raises(ValueError):
        res.row(0)
