"""Property-based engine tests (require the optional `hypothesis` dev dep).

Kept separate from test_engine.py so that a missing `hypothesis` degrades to
a skipped module instead of a collection error for the whole engine suite.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep; property tests skip without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data import synth  # noqa: E402

from test_engine import DS, _index  # noqa: E402


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_insert_delete_roundtrip_property(seed):
    """Inserting then deleting a doc restores search results exactly."""
    index, idx, val = _index(n_docs=48, seed=seed % 17)
    qi, qv = synth.make_queries(seed, DS, 1, pad=24)
    before, _ = index.search(qi[0], qv[0], k=10, kprime=48)
    extra_i, extra_v = synth.make_corpus(seed ^ 99, DS, 1, pad=48)
    index.insert(777, extra_i[0][extra_i[0] >= 0], extra_v[0][extra_i[0] >= 0])
    index.delete(777)
    after, _ = index.search(qi[0], qv[0], k=10, kprime=48)
    assert np.array_equal(before, after)
