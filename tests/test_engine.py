"""End-to-end behaviour of the Sinnamon engine (paper §4 + §6)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core.engine import EngineSpec, SinnamonIndex
from repro.core.linscan import LinScanIndex, brute_force_topk
from repro.data import synth
from repro.storage import vecstore

DS = synth.SparseDatasetSpec("t", n=500, psi_doc=24, psi_query=12,
                             value_dist="gaussian")


def _index(n_docs=300, value_dtype="float32", h=2, m=16, seed=3):
    idx, val = synth.make_corpus(0, DS, n_docs, pad=48)
    spec = EngineSpec(n=DS.n, m=m, capacity=((n_docs + 31) // 32) * 32,
                      max_nnz=48, h=h, seed=seed, value_dtype=value_dtype)
    index = SinnamonIndex(spec)
    index.insert_many(list(range(n_docs)), idx, val)
    return index, idx, val


@pytest.fixture(scope="module")
def built():
    return _index()


def test_scores_upper_bound(built):
    """Theorem 5.1: Algorithm 6 scores upper-bound the exact inner product."""
    index, idx, val = built
    qi, qv = synth.make_queries(1, DS, 8, pad=24)
    for b in range(8):
        s = eng.score(index.state, index.spec, jnp.asarray(qi[b]),
                      jnp.asarray(qv[b]))
        qd = vecstore.densify_query(DS.n, jnp.asarray(qi[b]),
                                    jnp.asarray(qv[b]))
        exact = vecstore.exact_scores_all(index.state.store, qd)
        active = np.asarray(index.state.active)
        gap = np.asarray(s)[active] - np.asarray(exact)[active]
        assert gap.min() >= -1e-4


def test_recall_vs_exact(built):
    index, idx, val = built
    qi, qv = synth.make_queries(2, DS, 16, pad=24)
    recalls = []
    for b in range(16):
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], DS.n, 10)
        ids, _ = index.search(qi[b], qv[b], k=10, kprime=60)
        recalls.append(len(set(ids.tolist()) & set(ids0.tolist())) / 10)
    assert np.mean(recalls) >= 0.9, recalls


def test_kprime_monotone_recall(built):
    """Paper Fig. 10: recall improves with k'."""
    index, idx, val = built
    qi, qv = synth.make_queries(3, DS, 12, pad=24)
    means = []
    for kprime in (10, 40, 160):
        rs = []
        for b in range(12):
            ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], DS.n, 10)
            ids, _ = index.search(qi[b], qv[b], k=10, kprime=kprime)
            rs.append(len(set(ids.tolist()) & set(ids0.tolist())) / 10)
        means.append(np.mean(rs))
    assert means[0] <= means[1] + 0.05 and means[1] <= means[2] + 0.05
    assert means[2] >= means[0]


def test_anytime_budget(built):
    """Anytime lever: tiny budget still returns; full budget is better."""
    index, idx, val = built
    qi, qv = synth.make_queries(4, DS, 12, pad=24)
    r_small, r_full = [], []
    for b in range(12):
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], DS.n, 10)
        for budget, acc in ((2, r_small), (None, r_full)):
            ids, _ = index.search(qi[b], qv[b], k=10, kprime=60,
                                  budget=budget)
            acc.append(len(set(ids.tolist()) & set(ids0.tolist())) / 10)
    assert np.mean(r_full) >= np.mean(r_small)


def test_delete_and_recycle():
    index, idx, val = _index(n_docs=64)
    qi, qv = synth.make_queries(5, DS, 1, pad=24)
    ids0, _ = index.search(qi[0], qv[0], k=5, kprime=30)
    target = int(ids0[0])
    index.delete(target)
    ids1, _ = index.search(qi[0], qv[0], k=5, kprime=30)
    assert target not in ids1
    # slot recycling: new doc reuses the freed slot (paper §4.3)
    free_before = len(index._free)
    nid, nidx, nval = 9999, idx[0], val[0]
    index.insert(nid, nidx[nidx >= 0], nval[nidx >= 0])
    assert len(index._free) == free_before - 1
    ids2, _ = index.search(qi[0], qv[0], k=64, kprime=64)
    assert nid in ids2 or index.size == 64


def test_constrained_search(built):
    """Eq. (3): filter mask excludes documents from the result set."""
    index, idx, val = built
    qi, qv = synth.make_queries(6, DS, 1, pad=24)
    ids0, _ = index.search(qi[0], qv[0], k=10, kprime=60)
    mask = np.ones(index.spec.capacity, bool)
    slots = [index._id2slot[int(d)] for d in ids0[:5]]
    mask[slots] = False
    ids1, _ = index.search(qi[0], qv[0], k=10, kprime=60,
                           filter_mask=jnp.asarray(mask))
    assert not set(ids0[:5].tolist()) & set(ids1.tolist())


def test_grow_preserves_content():
    index, idx, val = _index(n_docs=64)
    qi, qv = synth.make_queries(7, DS, 1, pad=24)
    before, _ = index.search(qi[0], qv[0], k=10, kprime=40)
    index.grow(256)
    after, _ = index.search(qi[0], qv[0], k=10, kprime=40)
    assert np.array_equal(before, after)
    assert index.spec.capacity == 256


def test_update_overwrites():
    index, idx, val = _index(n_docs=32)
    keep = idx[0] >= 0
    index.insert(0, idx[1][idx[1] >= 0], val[1][idx[1] >= 0])  # overwrite doc 0
    assert index.size == 32


def test_insert_many_overwrites():
    """Batch insert shares insert()'s overwrite semantics: a live id is
    replaced (stale slot freed, never left active), and only the LAST
    occurrence of an in-batch duplicate survives — same as the sharded
    index, so a sharded WAL replays identically onto a single index."""
    index, idx, val = _index(n_docs=32)
    free_before = len(index._free)
    index.insert_many([0, 1], idx[2:4], val[2:4])      # overwrite live 0, 1
    assert index.size == 32
    assert len(index._free) == free_before             # stale slots recycled
    assert int(np.asarray(index.state.active).sum()) == 32
    index.insert_many([40, 40], idx[4:6], val[4:6])    # in-batch duplicate
    assert index.size == 33
    assert int(np.asarray(index.state.active).sum()) == 33
    slot = index._id2slot[40]
    np.testing.assert_array_equal(
        np.asarray(index.state.store.indices[slot]), idx[5])


def test_memory_accounting(built):
    index, _, _ = built
    mem = index.memory_bytes()
    assert mem["sketch"] == 2 * index.spec.m * index.spec.capacity * 2
    assert mem["inverted_index"] == index.spec.n * (index.spec.capacity // 32) * 4
    assert mem["index_total"] < mem["storage"] + mem["index_total"]


def test_sinnamon_plus_nonnegative():
    ds = dataclasses.replace(DS, nonneg=True, value_dist="lognormal",
                             value_param=0.5)
    idx, val = synth.make_corpus(11, ds, 128, pad=48)
    spec = EngineSpec(n=ds.n, m=16, capacity=128, max_nnz=48, h=1,
                      positive_only=True, value_dtype="float32")
    index = SinnamonIndex(spec)
    index.insert_many(list(range(128)), idx, val)
    qi, qv = synth.make_queries(12, ds, 8, pad=24)
    rec = []
    for b in range(8):
        ids0, _ = brute_force_topk(idx, val, qi[b], qv[b], ds.n, 10)
        ids, _ = index.search(qi[b], qv[b], k=10, kprime=60)
        rec.append(len(set(ids.tolist()) & set(ids0.tolist())) / 10)
    assert np.mean(rec) >= 0.9


# The hypothesis-based insert/delete round-trip property lives in
# tests/test_engine_property.py so a missing optional `hypothesis` degrades
# to ONE skipped module instead of erroring this whole suite at collection.
