#!/usr/bin/env python
"""Build the API reference site with pdoc, treating pdoc warnings as errors.

    pip install pdoc
    python docs/build.py [-o docs/_build] [--if-available]

Documents the retrieval system packages and excludes the dormant seed
scaffolding (see configs/README.md) so the site never indexes dead surface.
Target modules are imported *before* pdoc runs, so pre-existing import-time
warnings from third-party libraries don't mask real documentation problems;
during the pdoc pass, any warning raised from pdoc itself (unparseable
docstring/annotation, unresolvable reference) fails the build — that is the
CI "docs" job's warnings-as-errors gate.
"""

from __future__ import annotations

import argparse
import importlib
import pathlib
import pkgutil
import sys
import warnings

# pdoc module specs: document `repro`, minus the dormant seed scaffolding.
EXCLUDED = ("repro.configs", "repro.models", "repro.optim", "repro.train")
MODULE_SPECS = ["repro"] + [f"!{mod}" for mod in EXCLUDED]


def _preimport() -> None:
    """Import every documented module once, before warnings are recorded."""
    import repro

    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        if info.name.startswith(EXCLUDED):
            continue
        importlib.import_module(info.name)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("-o", "--output", default="docs/_build",
                    help="output directory for the generated site")
    ap.add_argument("--if-available", action="store_true",
                    help="exit 0 (instead of 2) when pdoc is not installed "
                         "— local convenience; CI installs pdoc")
    args = ap.parse_args()

    root = pathlib.Path(__file__).resolve().parent.parent
    src = str(root / "src")
    if src not in sys.path:
        sys.path.insert(0, src)

    try:
        import pdoc
    except ImportError:
        print("pdoc is not installed (`pip install pdoc`); API reference "
              "not built", file=sys.stderr)
        sys.exit(0 if args.if_available else 2)

    _preimport()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        pdoc.pdoc(*MODULE_SPECS, output_directory=pathlib.Path(args.output))

    failures = 0
    for w in caught:
        origin = f"{w.filename}:{w.lineno}"
        if "pdoc" in pathlib.Path(w.filename).parts or "pdoc" in w.filename:
            print(f"error (pdoc warning): {w.category.__name__}: "
                  f"{w.message} [{origin}]", file=sys.stderr)
            failures += 1
        else:
            print(f"note (third-party warning, ignored): "
                  f"{w.category.__name__}: {w.message} [{origin}]",
                  file=sys.stderr)
    if failures:
        sys.exit(f"{failures} pdoc warning(s) treated as errors")
    print(f"API reference written to {args.output}")


if __name__ == "__main__":
    main()
