#!/usr/bin/env python
"""Link-check the repo's markdown: every relative link must resolve.

    python docs/check_links.py [files...]

With no arguments, checks README.md, docs/*.md and configs/README.md.
Skipped on purpose: absolute http(s)/mailto links (no network in CI gates)
and links that escape the repository root (GitHub-web relative URLs like the
README's ``../../actions/...`` badge target).  Exit code 1 lists every
broken link with its file and line.
"""

from __future__ import annotations

import pathlib
import re
import sys

# Markdown inline links/images: [text](target) — target up to the first
# unescaped closing paren, excluding whitespace (titles are not used here).
_LINK = re.compile(r"\]\(([^)\s]+)\)")


def iter_links(path: pathlib.Path):
    text = path.read_text(encoding="utf-8")
    for match in _LINK.finditer(text):
        line = text.count("\n", 0, match.start()) + 1
        yield line, match.group(1)


def check(files, root: pathlib.Path) -> list:
    broken = []
    for path in files:
        for line, target in iter_links(path):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            try:
                resolved.relative_to(root)
            except ValueError:
                continue                    # escapes the repo: a web URL
            if not resolved.exists():
                broken.append((path, line, target))
    return broken


def main() -> None:
    root = pathlib.Path(__file__).resolve().parent.parent
    if len(sys.argv) > 1:
        files = [pathlib.Path(a).resolve() for a in sys.argv[1:]]
    else:
        files = sorted((root / "docs").glob("*.md"))
        files.append(root / "README.md")
        files.append(root / "src" / "repro" / "configs" / "README.md")
        files = [f for f in files if f.exists()]
    broken = check(files, root)
    checked = len(files)
    if broken:
        for path, line, target in broken:
            print(f"{path.relative_to(root)}:{line}: broken link -> "
                  f"{target}", file=sys.stderr)
        sys.exit(f"{len(broken)} broken link(s) across {checked} file(s)")
    print(f"{checked} file(s) checked, all relative links resolve")


if __name__ == "__main__":
    main()
