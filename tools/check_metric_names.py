"""Lint: the metric catalog in docs/observability.md matches the code.

Two directions, plus naming conventions (run in the CI ``docs`` job;
exits non-zero with one line per violation):

1. every metric family registered at runtime (AST scan of ``src/`` for
   ``counter(...)`` / ``gauge(...)`` / ``histogram(...)`` calls with a
   literal ``repro_*`` first argument) appears in the docs catalog —
   an undocumented metric is invisible to operators;
2. every name in the catalog appears in the code — a stale docs row
   sends an operator hunting for a series that no longer exists;
3. the type recorded in the docs table matches the registration call;
4. suffix conventions, so dashboards can infer units from names:
   counters end ``_total``; histograms end in a unit suffix
   (``_ms`` / ``_bytes`` / ``_docs`` / ``_size``); gauges never end
   ``_total`` (that spelling promises a monotone counter).

The scan keys on registration calls, not bare string constants, so
strings that merely *mention* a metric (the SLO monitor reading
existing families, tests, docstrings) can't introduce phantom names.
"""

from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
DOC = os.path.join(ROOT, "docs", "observability.md")

NAME_RE = re.compile(r"^repro_[a-z0-9_]+$")
#: registration call names -> metric type ("_hist" is QueryServer's cached
#: histogram wrapper; "gauge" also catches repro.obs.instrument's local
#: helper, called as a plain name).
REGISTRATION_FNS = {"counter": "counter", "gauge": "gauge",
                    "histogram": "histogram", "_hist": "histogram"}
HISTOGRAM_SUFFIXES = ("_ms", "_bytes", "_docs", "_size")
#: `name{labels}` or bare `name` inside a docs table cell
_DOC_TOKEN_RE = re.compile(r"`(repro_[a-z0-9_]+)(?:\{[^}]*\})?`")


def scan_code(src_dir: str = SRC) -> dict:
    """{metric_name: {types}} from registration call sites under src/."""
    found: dict = {}
    for dirpath, _dirs, files in os.walk(src_dir):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read(), filename=path)
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call) or not node.args:
                    continue
                fn = node.func
                fn_name = (fn.attr if isinstance(fn, ast.Attribute)
                           else fn.id if isinstance(fn, ast.Name) else None)
                mtype = REGISTRATION_FNS.get(fn_name)
                arg = node.args[0]
                if mtype is None or not isinstance(arg, ast.Constant) \
                        or not isinstance(arg.value, str):
                    continue
                if arg.value.startswith("repro_"):
                    found.setdefault(arg.value, set()).add(mtype)
    return found


def scan_docs(doc_path: str = DOC) -> dict:
    """{metric_name: type} from the catalog tables in observability.md."""
    found: dict = {}
    with open(doc_path, encoding="utf-8") as f:
        for line in f:
            if not line.lstrip().startswith("|"):
                continue
            cells = [c.strip() for c in line.strip().strip("|").split("|")]
            if len(cells) < 2:
                continue
            names = _DOC_TOKEN_RE.findall(cells[0])
            if not names:
                continue
            mtype = cells[1].lower()
            if mtype not in ("counter", "gauge", "histogram"):
                continue
            for name in names:
                found[name] = mtype
    return found


def check() -> list:
    problems = []
    code = scan_code()
    docs = scan_docs()

    for name, types in sorted(code.items()):
        if len(types) > 1:
            problems.append(f"{name}: registered as multiple types "
                            f"({', '.join(sorted(types))})")
    for name in sorted(code):
        if name not in docs:
            problems.append(f"{name}: registered in src/ but missing from "
                            f"the docs/observability.md catalog")
    for name in sorted(docs):
        if name not in code:
            problems.append(f"{name}: in the docs/observability.md catalog "
                            f"but never registered in src/")
    for name, mtype in sorted(docs.items()):
        types = code.get(name)
        if types and mtype not in types:
            problems.append(f"{name}: docs say {mtype}, code registers "
                            f"{'/'.join(sorted(types))}")

    for name, types in sorted(code.items()):
        if not NAME_RE.match(name):
            problems.append(f"{name}: not snake_case ascii "
                            f"(^repro_[a-z0-9_]+$)")
        mtype = next(iter(types)) if len(types) == 1 else None
        if mtype == "counter" and not name.endswith("_total"):
            problems.append(f"{name}: counters must end _total")
        if mtype == "histogram" and not name.endswith(HISTOGRAM_SUFFIXES):
            problems.append(f"{name}: histograms must end one of "
                            f"{'/'.join(HISTOGRAM_SUFFIXES)}")
        if mtype == "gauge" and name.endswith("_total"):
            problems.append(f"{name}: gauges must not end _total "
                            f"(reserved for counters)")
    return problems


def main() -> int:
    problems = check()
    code, docs = scan_code(), scan_docs()
    if problems:
        for p in problems:
            print(f"check_metric_names: {p}", file=sys.stderr)
        return 1
    print(f"check_metric_names: OK — {len(code)} registered families, "
          f"{len(docs)} documented, names/types/suffixes consistent")
    return 0


if __name__ == "__main__":
    sys.exit(main())
